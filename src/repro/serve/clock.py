"""Injectable time source for the serving layer (DESIGN.md §15).

Everything time-shaped in ``repro.serve`` — request ``submitted_at``
stamps, latency accounting, admission deadlines, shed decisions — reads
the clock through this one seam.  Production uses :class:`SystemClock`
(``time.perf_counter`` / ``time.sleep``); tests and the open-loop replay
harness (``repro.serve.replay``) use :class:`VirtualClock`, whose time
only moves when the harness advances it.  That is what makes scheduler
behavior — packing order, steal decisions, shed decisions, latency
percentiles — bit-for-bit reproducible in CI: two replays of the same
seeded trace observe the *identical* sequence of timestamps, so every
time-dependent branch resolves the same way (tests/test_serve_replay.py
asserts bitwise-equal retirement logs).

Both clocks share one interface, so the replay loop has a single code
path: ``clock.sleep(dt)`` really sleeps on the system clock and simply
advances virtual time on the virtual one.
"""

from __future__ import annotations

import time


class Clock:
    """Time-source interface: monotonic ``now()`` plus ``sleep(dt)``."""

    def now(self) -> float:
        raise NotImplementedError

    def sleep(self, dt: float) -> None:
        raise NotImplementedError


class SystemClock(Clock):
    """Wall-clock time (monotonic): the production default."""

    def now(self) -> float:
        return time.perf_counter()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


class VirtualClock(Clock):
    """Deterministic manually-advanced time for tests and replay.

    ``sleep`` advances time instantly — the replay harness models the
    cost of a scheduler tick as a deterministic function of the work it
    ran and "sleeps" that long, so latency percentiles are exact
    arithmetic on the trace, never measurements.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def sleep(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"cannot sleep a negative duration ({dt})")
        self._t += dt

    # alias: harness code reads better as clock.advance(dt)
    advance = sleep
