"""Open-loop traffic replay harness (DESIGN.md §15).

Closed-loop benchmarking (submit a batch, drain, time it) measures a
system that never experiences queueing: the load adapts to the service.
Real traffic is *open-loop* — arrivals happen on their own schedule
whether or not the service keeps up — and that is the regime where
continuous batching, admission control and load shedding earn their
keep.  This module generates seeded open-loop traces and replays them
against a :class:`~repro.serve.service.SolverService` on a virtual
clock, so the resulting goodput / latency-percentile / utilization
numbers are exact deterministic arithmetic (CI-gateable, zero timing
flake) rather than wall-clock measurements.

* **Arrival process** — Poisson: exponential inter-arrival gaps at a
  configured rate, from a seeded ``numpy`` Generator.
* **Solve-size mix** — heavy-tailed over :class:`TrafficClass` entries
  (operator × tolerance × deadline, with a weight).  A tolerance is a
  slab-key ingredient, so a loose-tol/tight-tol mix both spreads solve
  *cost* over orders of magnitude (few iterations vs many) and
  exercises the multi-slab scheduler with genuinely distinct slabs.
* **Virtual time** — the replay loop models the cost of a scheduler
  tick as ``tick_overhead_s + iter_time_s * chunk_iters * slabs_run``
  and advances the service's clock by exactly that; between due
  arrivals with an idle service it jumps straight to the next arrival.
  Under a :class:`~repro.serve.clock.SystemClock` the same loop really
  sleeps, so the harness doubles as a live traffic generator.

The :class:`ReplayReport` carries the determinism witnesses —
retirement log, steal log, shed ids — plus the SLO economics: goodput
(SLO-met solves per second of virtual time), p50/p99 latency, and slab
slot-utilization (occupied-slot-iterations / capacity), the metric that
separates continuous injection from drain-to-empty serving
(BENCH_serve.json gates all three).
"""

from __future__ import annotations

import dataclasses
from typing import Hashable

import numpy as np

from repro.serve.errors import AdmissionRejected
from repro.serve.service import SolverService


@dataclasses.dataclass(frozen=True)
class TrafficClass:
    """One request population in the mix: operator + tolerance (the slab
    key) + SLO deadline, drawn with probability proportional to
    ``weight``."""

    op_key: Hashable
    n: int                             # RHS length (operator size)
    weight: float = 1.0
    tol: float = 1e-8
    deadline_s: float | None = None


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One traced request: arrives at absolute time ``t``."""

    t: float
    op_key: Hashable
    b: np.ndarray
    tol: float
    deadline_s: float | None


def poisson_trace(classes: list[TrafficClass], rate_per_s: float,
                  n_requests: int, seed: int) -> list[Arrival]:
    """Seeded open-loop trace: Poisson arrivals at ``rate_per_s``, each
    request drawn from the heavy-tail class mix, RHS columns standard
    normal.  Same seed -> bitwise-identical trace."""
    if rate_per_s <= 0:
        raise ValueError(f"rate_per_s must be > 0 ({rate_per_s})")
    if not classes:
        raise ValueError("need at least one TrafficClass")
    rng = np.random.default_rng(seed)
    w = np.asarray([c.weight for c in classes], dtype=float)
    if (w <= 0).any():
        raise ValueError("class weights must be > 0")
    p = w / w.sum()
    out: list[Arrival] = []
    t = 0.0
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate_per_s)
        c = classes[int(rng.choice(len(classes), p=p))]
        out.append(Arrival(t=t, op_key=c.op_key,
                           b=rng.standard_normal(c.n), tol=c.tol,
                           deadline_s=c.deadline_s))
    return out


@dataclasses.dataclass
class ReplayReport:
    """Outcome of one open-loop replay (all times in the service
    clock's frame — virtual seconds under a VirtualClock)."""

    n_arrivals: int
    n_retired: int
    n_converged: int
    n_slo_met: int
    n_shed: int
    n_rejected: int
    makespan_s: float                  # first arrival -> last retirement
    offered_per_s: float               # arrival rate actually traced
    goodput_per_s: float               # SLO-met solves / makespan
    latency_p50_s: float
    latency_p99_s: float
    slot_utilization: float
    ticks: int
    chunks_run: int
    # Determinism witnesses: bitwise-comparable across replays.
    retirement_log: list[tuple[int, int, int, float]]
    steal_log: list[tuple]
    shed_ids: list[int]
    rejected_arrivals: list[int]       # indices into the trace

    def metrics(self) -> dict:
        """Flat JSON-able metric dict (for BENCH_serve.json gates)."""
        return {
            "replay_arrivals": self.n_arrivals,
            "replay_retired": self.n_retired,
            "replay_converged": self.n_converged,
            "replay_slo_met": self.n_slo_met,
            "replay_shed": self.n_shed,
            "replay_rejected": self.n_rejected,
            "replay_makespan_s": self.makespan_s,
            "replay_offered_per_s": self.offered_per_s,
            "replay_goodput_per_s": self.goodput_per_s,
            "replay_p50_s": self.latency_p50_s,
            "replay_p99_s": self.latency_p99_s,
            "replay_slot_utilization": self.slot_utilization,
            "replay_ticks": self.ticks,
            "replay_chunks_run": self.chunks_run,
        }


def _percentile(sorted_vals: list[float], p: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(int(p / 100 * len(sorted_vals)), len(sorted_vals) - 1)
    return sorted_vals[i]


def replay(svc: SolverService, trace: list[Arrival], *,
           iter_time_s: float = 1e-4, tick_overhead_s: float = 1e-4,
           max_ticks: int = 200_000) -> ReplayReport:
    """Drive ``svc`` through an open-loop ``trace``.

    Each loop turn submits every arrival whose time has come (admission
    rejections are recorded, not fatal), runs one scheduler tick, and
    advances the service clock by the modeled tick cost — so queueing
    delay emerges exactly as in a real open-loop system: when offered
    load outruns the slabs, arrivals pile up during ticks and latency
    grows.  With an idle service the clock jumps to the next arrival.
    """
    clock = svc.clock
    results: list = []
    rejected: list[int] = []
    i = 0
    ticks = 0
    while i < len(trace) or svc.pending > 0:
        while i < len(trace) and trace[i].t <= clock.now():
            a = trace[i]
            try:
                svc.submit(a.op_key, a.b, tol=a.tol,
                           deadline_s=a.deadline_s)
            except AdmissionRejected:
                rejected.append(i)
            i += 1
        if svc.pending == 0:
            if i >= len(trace):
                break
            clock.sleep(trace[i].t - clock.now())   # idle: jump ahead
            continue
        before = svc.scheduler.chunks_run
        results.extend(svc.step())
        ran = svc.scheduler.chunks_run - before
        clock.sleep(tick_overhead_s + iter_time_s * svc.chunk_iters * ran)
        ticks += 1
        if ticks > max_ticks:
            raise RuntimeError(f"replay: exceeded {max_ticks} ticks "
                               f"({svc.pending} requests still pending)")
    solved = [r for r in results if not r.shed]
    lats = sorted(r.latency_s for r in solved)
    t0 = trace[0].t if trace else 0.0
    t_end = max((t for _rid, _w, _tick, t in svc.retirement_log),
                default=t0)
    makespan = max(t_end - t0, 1e-12)
    n_met = sum(r.slo_met for r in results)
    offered = (len(trace) / max(trace[-1].t - t0, 1e-12)) if len(trace) > 1 \
        else 0.0
    return ReplayReport(
        n_arrivals=len(trace),
        n_retired=len(solved),
        n_converged=sum(r.converged for r in solved),
        n_slo_met=n_met,
        n_shed=sum(r.shed for r in results),
        n_rejected=len(rejected),
        makespan_s=makespan,
        offered_per_s=offered,
        goodput_per_s=n_met / makespan,
        latency_p50_s=_percentile(lats, 50),
        latency_p99_s=_percentile(lats, 99),
        slot_utilization=svc.scheduler.slot_utilization(),
        ticks=ticks,
        chunks_run=svc.scheduler.chunks_run,
        retirement_log=list(svc.retirement_log),
        steal_log=list(svc.scheduler.steal_log),
        shed_ids=[r.req_id for r in results if r.shed],
        rejected_arrivals=rejected,
    )
