"""Operator / preconditioner setup cache (DESIGN.md §11).

Production solve traffic is repetitive: many right-hand sides against few
operators.  The expensive per-operator setup — probing + factorizing the
block-Jacobi preconditioner (``BlockJacobi.from_operator`` costs
``n_colors * block_size`` operator applications plus ``nb`` dense
inversions), estimating spectral bounds for the Chebyshev shift schedule —
must be paid once per *operator*, not once per request.  The cache keys on
a content fingerprint of the operator (type + dataclass fields, arrays
hashed by bytes), so two structurally identical operators share one setup
even when they are distinct Python objects.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable

import numpy as np

from repro.core.chebyshev import shifts_for_operator
from repro.linalg.preconditioners import BlockJacobi, JacobiPrec
from repro.obs.metrics import MetricsRegistry


def operator_fingerprint(op: Any) -> str:
    """Content hash of an operator (or any dataclass-like object).

    Dataclass fields are hashed in declaration order; array-valued fields
    by shape/dtype/bytes.  Falls back to ``repr`` for non-dataclasses —
    adequate for the stencil/diagonal operators here, which are frozen
    dataclasses of scalars and arrays.
    """
    h = hashlib.sha1(type(op).__name__.encode())
    if dataclasses.is_dataclass(op):
        for f in dataclasses.fields(op):
            v = getattr(op, f.name)
            h.update(f.name.encode())
            if hasattr(v, "shape") and hasattr(v, "dtype"):
                a = np.asarray(v)
                h.update(str(a.shape).encode())
                h.update(str(a.dtype).encode())
                h.update(a.tobytes())
            else:
                h.update(repr(v).encode())
    else:
        h.update(repr(op).encode())
    return h.hexdigest()


class SetupCache:
    """Memoizes per-operator solver setup keyed by operator fingerprint.

    Hit/miss accounting lives on a :class:`MetricsRegistry` (DESIGN.md
    §16; ``SolverService`` passes its own so the cache shares the serve
    registry); the pre-§16 ``hits``/``misses`` ints remain as read-only
    views for one release.
    """

    def __init__(self, registry: MetricsRegistry | None = None):
        self._store: dict[tuple, Any] = {}
        self.registry = MetricsRegistry() if registry is None else registry
        m = self.registry
        self._c_hits = m.counter(
            "serve_setup_cache_hits_total",
            "operator setups served from the fingerprint cache",
            label_names=("kind",))
        self._c_misses = m.counter(
            "serve_setup_cache_misses_total",
            "operator setups built (cache miss)", label_names=("kind",))

    @property
    def hits(self) -> int:
        return int(sum(v[0] for v in self._c_hits.series().values()))

    @property
    def misses(self) -> int:
        return int(sum(v[0] for v in self._c_misses.series().values()))

    def get(self, kind: str, key: tuple, builder: Callable[[], Any]) -> Any:
        k = (kind, *key)
        if k in self._store:
            self._c_hits.labels(kind=kind).inc()
            return self._store[k]
        self._c_misses.labels(kind=kind).inc()
        val = builder()
        self._store[k] = val
        return val

    # ------------------------------------------------- cached setups ----
    def block_jacobi(self, op, block_size: int) -> BlockJacobi:
        # from_operator picks the right coupling reach per operator type
        # (stencils: block_size; SparseOp: measured bandwidth).
        fp = operator_fingerprint(op)
        return self.get("block_jacobi", (fp, block_size),
                        lambda: BlockJacobi.from_operator(op, block_size))

    def jacobi(self, op) -> JacobiPrec:
        fp = operator_fingerprint(op)
        return self.get("jacobi", (fp,),
                        lambda: JacobiPrec.from_operator(op))

    def partition(self, op, n_shards: int):
        """Partition plan of an unstructured operator (DESIGN.md §12):
        RCM ordering + send/recv index-set construction is setup-time
        numpy work on the same once-per-operator footing as the
        block-Jacobi factorization.  Keyed by operator fingerprint +
        shard count, and shared with the module-level memo the
        distributed path uses directly
        (``repro.linalg.partition.plan_for``), so a solve that already
        partitioned the operator is a hit here and vice versa."""
        from repro.linalg.partition import plan_for

        fp = operator_fingerprint(op)
        return self.get("partition", (fp, n_shards),
                        lambda: plan_for(op, n_shards))

    def sigmas(self, op, l: int, prec=None):
        """Chebyshev shift schedule — for the PRECONDITIONED operator when
        ``prec`` is given (the basis polynomial acts on M^{-1}A; shifts
        from the bare spectrum would be mis-scaled and break the basis
        down)."""
        fp = operator_fingerprint(op)
        pfp = None if prec is None else operator_fingerprint(prec)
        return self.get("sigmas", (fp, pfp, l),
                        lambda: shifts_for_operator(op, l, prec=prec))

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._store)}
