"""repro.serve — batched multi-RHS solver service (DESIGN.md §11).

The serving layer over the batched CG family: a request queue + dynamic
batcher packs (operator, b, tol) traffic into fixed-width slabs, the
backend-compiled slab program steps them with ONE amortized (K, s) global
reduction per iteration, masked retirement frees converged columns for
queued work without recompiling, and a fingerprint-keyed setup cache
makes repeat operators skip their block-Jacobi / shift setup.

    from repro.parallel import get_backend
    from repro.serve import SolverService

    svc = SolverService(get_backend("shard_map", n_shards=8),
                        s=8, method="plcg", l=2, prec="block_jacobi",
                        block_size=32)
    svc.register_operator("poisson", op)
    rid = svc.submit("poisson", b, tol=1e-8)
    results = svc.drain()
    print(results[rid].iters, svc.stats())

See ``examples/serve_solver.py`` (quickstart) and
``benchmarks/serve_bench.py`` (throughput / latency percentiles).
"""

from repro.serve.batcher import RequestQueue, SolveRequest
from repro.serve.cache import SetupCache, operator_fingerprint
from repro.serve.service import RequestResult, SolverService

__all__ = [
    "RequestQueue",
    "SolveRequest",
    "SetupCache",
    "operator_fingerprint",
    "RequestResult",
    "SolverService",
]
