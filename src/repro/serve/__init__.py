"""repro.serve — continuous-batching multi-RHS solver service
(DESIGN.md §11/§15).

The serving layer over the batched CG family: a request queue +
admission layer buckets (operator, b, tol, deadline) traffic, a
multi-slab work-stealing scheduler packs it into fixed-width slabs —
refilling retired slots at every chunk boundary so utilization stays
high mid-flight — each slab steps with ONE amortized (K, s) global
reduction per iteration, deadline-expired work is shed before it wastes
a slot, and a fingerprint-keyed setup cache makes repeat operators skip
their block-Jacobi / shift setup.  Every timestamp flows through an
injectable clock, so the whole scheduler is deterministic under the
open-loop traffic-replay harness (``repro.serve.replay``).

    from repro.parallel import get_backend
    from repro.serve import AdmissionPolicy, SolverService

    svc = SolverService(get_backend("shard_map", n_shards=8),
                        s=8, method="plcg", l=2, prec="block_jacobi",
                        block_size=32, max_replicas=2,
                        admission=AdmissionPolicy(max_pending=256))
    svc.register_operator("poisson", op)
    rid = svc.submit("poisson", b, tol=1e-8, deadline_s=2.0)
    results = svc.drain()
    print(results[rid].iters, svc.stats())

See ``examples/serve_solver.py`` (quickstart) and
``benchmarks/serve_bench.py`` (throughput / latency percentiles / the
open-loop replay section).
"""

from repro.serve.batcher import (AdmissionPolicy, RequestQueue,
                                 RetryPolicy, SolveRequest)
from repro.serve.cache import SetupCache, operator_fingerprint
from repro.serve.clock import Clock, SystemClock, VirtualClock
from repro.serve.errors import (AdmissionRejected, BadRequestError,
                                ConfigError, ServeError,
                                UnknownOperatorError)
from repro.serve.replay import (Arrival, ReplayReport, TrafficClass,
                                poisson_trace, replay)
from repro.serve.scheduler import SlabScheduler, SlabWorker
from repro.serve.service import RequestResult, SolverService

__all__ = [
    "AdmissionPolicy",
    "AdmissionRejected",
    "Arrival",
    "BadRequestError",
    "Clock",
    "ConfigError",
    "ReplayReport",
    "RequestQueue",
    "RequestResult",
    "RetryPolicy",
    "ServeError",
    "SetupCache",
    "SlabScheduler",
    "SlabWorker",
    "SolveRequest",
    "SolverService",
    "SystemClock",
    "TrafficClass",
    "UnknownOperatorError",
    "VirtualClock",
    "operator_fingerprint",
    "poisson_trace",
    "replay",
]
