"""Multi-slab work-stealing scheduler — continuous batching for solves
(DESIGN.md §15).

One slab amortizes the per-iteration global reduction over its s columns
(arXiv:1905.06850's win, batched: DESIGN.md §11), but a *service* has
more than one slab's worth of traffic: several slab keys (operators ×
tolerances) in flight at once, and hot keys whose queue outruns a single
slab.  This module runs a pool of :class:`SlabWorker`\\ s — each one
compiled slab state bound to a slab key — under a deterministic
work-stealing scheduler:

* **replication** — when every worker for a key has a backlog past the
  ``replicate_watermark``, a replica spawns.  Replicas SHARE the key's
  compiled :class:`~repro.core.batched.SlabProgram` (same jitted
  callables, separate state arrays), so scale-out never recompiles.
* **work stealing** — a worker with free slots and an empty local queue
  steals from the deepest-backlog sibling of the same key, taking from
  the TAIL of the victim's queue (the classic owner-pops-head /
  thief-pops-tail discipline, which preserves the victim's FIFO head).
  Every steal is logged; with a virtual clock two replays of the same
  trace produce identical steal logs (tests/test_serve_replay.py).
* **continuous injection** — freed slots are refilled from the local
  queue at every chunk boundary (``SlabProgram.inject``, fixed shapes,
  no retrace), so slot-utilization stays high mid-flight instead of
  decaying as the slab drains.  ``continuous=False`` gives the
  drain-to-empty baseline the BENCH_serve replay section compares
  against.
* **load shedding** — queued requests whose deadline already expired
  are dropped at pack time (they could no longer meet their SLO; see
  ``AdmissionPolicy.shed_expired``), keeping slots for work that still
  counts toward goodput.

Every decision — dispatch target, steal victim, shed verdict, tick
order — is a pure function of the submission sequence and the injected
clock (``repro.serve.clock``): no wall-clock reads, no unordered-dict
iteration, no randomness.  That determinism is what the replay test
harness (``repro.serve.replay``) asserts bit-for-bit.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Hashable, NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.batched import SlabProgram, slab_slot_iterations
from repro.obs.metrics import MetricsRegistry
from repro.serve.batcher import SlabKey, SolveRequest
from repro.serve.errors import WorkerFault

try:                                     # jax >= 0.4.14
    from jax.errors import JaxRuntimeError as _JaxRuntimeError
except ImportError:                      # pragma: no cover - old jax
    class _JaxRuntimeError(Exception):
        """Placeholder: never raised when jax lacks JaxRuntimeError."""

# Exceptions the scheduler treats as "this worker's backing
# program/process died" (tear down + resubmit) rather than a scheduler
# bug (propagate).  Injected chaos faults raise WorkerFault directly;
# a dead fabric rank surfaces as a jax runtime error at dispatch/poll.
WORKER_FAULT_TYPES = (WorkerFault, _JaxRuntimeError)


class StealEvent(NamedTuple):
    """One work-steal: ``thief`` took ``req_id`` from ``victim``'s tail."""

    tick: int
    thief: int
    victim: int
    req_id: int


class ShedEvent(NamedTuple):
    """One load-shed: ``req_id`` dropped unstarted at ``t`` — its
    deadline had already passed after ``waited_s`` in queue."""

    tick: int
    worker: int
    req_id: int
    t: float
    waited_s: float


class DeathEvent(NamedTuple):
    """One worker teardown: ``worker`` faulted at ``tick``; its
    unretired requests (``req_ids``) went back to the service for
    resubmission through the retry policy."""

    tick: int
    worker: int
    req_ids: tuple[int, ...]
    reason: str


class RetiredColumn(NamedTuple):
    """One retired slab column, before the service wraps it in a
    :class:`~repro.serve.service.RequestResult`."""

    worker: int
    req: SolveRequest
    x: np.ndarray
    iters: int
    converged: bool
    res_history: np.ndarray


class SlabWorker:
    """One slab's runtime state: compiled program + slots + local queue.

    Host→device traffic is column-granular (DESIGN.md §15): the full
    (n, s) slab uploads exactly once (first init); afterwards only the
    columns an inject actually changed cross the host boundary
    (``B_dev.at[:, cols].set``).  ``uploaded_cols`` counts columns
    transferred, ``full_uploads`` whole-slab transfers — the regression
    test in tests/test_serve.py pins both.
    """

    def __init__(self, wid: int, key: SlabKey, program: SlabProgram):
        self.wid = wid
        self.key = key
        self.program = program
        self.s = program.s
        self.B = np.zeros((program.n, program.s))
        self.slots: list[SolveRequest | None] = [None] * program.s
        self.local: deque[SolveRequest] = deque()
        self.state = None
        self.B_dev = None
        # Utilization accounting (occupied-slot-iterations / capacity).
        self._iters_base = np.zeros(program.s, dtype=np.int64)
        self.occupied_slot_iters = 0
        self.capacity_slot_iters = 0
        # Transfer accounting.
        self.uploaded_cols = 0
        self.full_uploads = 0

    # ------------------------------------------------------------ views --
    def free_slots(self) -> list[int]:
        return [j for j, r in enumerate(self.slots) if r is None]

    def occupied(self) -> list[int]:
        return [j for j, r in enumerate(self.slots) if r is not None]

    def backlog(self) -> int:
        return len(self.local)

    def load(self) -> int:
        """Dispatch metric: queued + in-flight requests."""
        return len(self.local) + len(self.occupied())

    # ------------------------------------------------------------- pack --
    def pack(self, incoming: list[SolveRequest]) -> None:
        """Fill free slots from ``incoming`` (already admission-checked
        and shed-filtered), uploading ONLY the changed columns."""
        free = self.free_slots()
        assert len(incoming) <= len(free)
        if self.state is None:
            # First pack: one full upload, init the whole slab (zero
            # padding columns retire at iteration 0 — exact).
            for j, req in zip(free, incoming):
                self.B[:, j] = req.b
                self.slots[j] = req
            self.B_dev = jnp.asarray(self.B)
            self.uploaded_cols += self.s
            self.full_uploads += 1
            self.state = self.program.init(self.B_dev)
            self._iters_base[:] = 0
            return
        if not incoming:
            return                      # nothing changed: zero transfer
        refresh = np.zeros((self.s,), dtype=bool)
        cols = []
        for j, req in zip(free, incoming):
            self.B[:, j] = req.b
            self.slots[j] = req
            refresh[j] = True
            cols.append(j)
        idx = np.asarray(cols)
        self.B_dev = self.B_dev.at[:, idx].set(jnp.asarray(self.B[:, idx]))
        self.uploaded_cols += len(cols)
        self.state = self.program.inject(self.B_dev, self.state,
                                         jnp.asarray(refresh))
        self._iters_base[idx] = 0

    # ------------------------------------------------------ chunk + poll --
    def poll(self) -> list[RetiredColumn]:
        """Post-chunk bookkeeping: utilization accounting, then retire
        every occupied column whose loop has stopped."""
        stat = self.program.status(self.B_dev, self.state)
        running = np.asarray(stat.running)
        iters_now = np.asarray(stat.iters)
        self.occupied_slot_iters += slab_slot_iterations(
            self._iters_base, iters_now)
        self.capacity_slot_iters += self.s * self.program.chunk_iters
        self._iters_base = iters_now.copy()   # np view of a jax array is
        # read-only; pack() writes zeros into injected slots
        done = [j for j in self.occupied() if not running[j]]
        if not done:
            return []
        res = self.program.extract(self.B_dev, self.state)
        x = np.asarray(res.x)
        iters = np.asarray(res.iters)
        conv = np.asarray(res.converged)
        hist = np.asarray(res.res_history)
        out = []
        for j in done:
            req = self.slots[j]
            h = hist[j]
            out.append(RetiredColumn(
                worker=self.wid, req=req, x=x[j], iters=int(iters[j]),
                converged=bool(conv[j]), res_history=h[h >= 0]))
            self.slots[j] = None
        return out

    def slot_utilization(self) -> float:
        if not self.capacity_slot_iters:
            return 0.0
        return self.occupied_slot_iters / self.capacity_slot_iters


@dataclasses.dataclass
class TickReport:
    """What one scheduler tick did (the service turns this into results
    and telemetry).  ``failed`` are the in-flight/queued requests of
    workers that died this tick — NOT results: the service resubmits
    them through the retry policy or shed-records them."""

    retired: list[RetiredColumn]
    shed: list[SolveRequest]
    chunks_run: int
    failed: list[SolveRequest] = dataclasses.field(default_factory=list)
    deaths: list[DeathEvent] = dataclasses.field(default_factory=list)


class SlabScheduler:
    """Deterministic multi-slab scheduler (DESIGN.md §15).

    ``make_program`` compiles a :class:`SlabProgram` for a slab key on
    first use; replicas of the same key share it.  Dispatch sends each
    request to the least-loaded worker of its key (ties broken by
    worker id), spawning the first worker — or a replica, when every
    existing worker's backlog is at or past
    ``replicate_watermark * s`` and ``max_replicas`` allows — on demand.
    """

    def __init__(self, make_program: Callable[[SlabKey], SlabProgram], *,
                 max_replicas: int = 1, replicate_watermark: float = 1.0,
                 steal: bool = True, continuous: bool = True,
                 shed_expired: bool = True,
                 registry: MetricsRegistry | None = None,
                 fault_injector: Callable[[int, SlabWorker], None]
                 | None = None):
        if max_replicas < 1:
            raise ValueError(f"max_replicas must be >= 1 ({max_replicas})")
        self.make_program = make_program
        self.max_replicas = int(max_replicas)
        self.replicate_watermark = float(replicate_watermark)
        self.steal = steal
        self.continuous = continuous
        self.shed_expired = shed_expired
        # fault_injector(tick, worker) runs before each busy worker's
        # chunk dispatch; raising WorkerFault simulates a backing
        # process death at a deterministic tick (the serve recovery
        # drill's injection point — DESIGN.md §19).
        self.fault_injector = fault_injector
        self.workers: list[SlabWorker] = []
        self._next_wid = 0               # wids never reuse: a respawned
        # worker is a NEW identity (death/steal/shed logs stay unambiguous)
        self._by_key: dict[SlabKey, list[SlabWorker]] = {}
        self._programs: dict[SlabKey, SlabProgram] = {}
        # Event LOGS stay — they are the bitwise determinism witnesses the
        # replay tests compare; the registry carries the aggregate COUNTS
        # (DESIGN.md §16).  tests/test_serve.py asserts log-length ==
        # counter parity.
        self.steal_log: list[StealEvent] = []
        self.shed_log: list[ShedEvent] = []
        self.death_log: list[DeathEvent] = []
        self.ticks = 0
        self.chunks_run = 0
        self.registry = MetricsRegistry() if registry is None else registry
        m = self.registry
        self._c_steals = m.counter(
            "serve_steals_total",
            "requests stolen from a same-key sibling's queue tail",
            label_names=("thief",))
        self._c_sheds = m.counter(
            "serve_sheds_total",
            "queued requests dropped at pack time (deadline expired)")
        self._c_ticks = m.counter(
            "serve_ticks_total", "scheduler ticks run")
        self._c_chunks = m.counter(
            "serve_chunks_total", "slab chunks dispatched")
        self._c_deaths = m.counter(
            "serve_worker_deaths_total",
            "slab workers torn down after a backing fault")

    # --------------------------------------------------------- dispatch --
    def _spawn(self, key: SlabKey) -> SlabWorker:
        # Replacement workers for a key whose predecessor died reuse the
        # cached compiled program: respawn never recompiles.
        prog = self._programs.get(key)
        if prog is None:
            prog = self._programs[key] = self.make_program(key)
        w = SlabWorker(self._next_wid, key, prog)
        self._next_wid += 1
        self.workers.append(w)
        self._by_key.setdefault(key, []).append(w)
        return w

    def _fail_worker(self, w: SlabWorker, exc: BaseException,
                     deaths: list[DeathEvent]) -> list[SolveRequest]:
        """Tear down a faulted worker: harvest its unretired in-flight
        slots and local queue (the service resubmits them), remove it
        from the pool, and log the death.  The key's compiled program
        stays cached — the next dispatch for the key spawns a fresh
        worker without recompiling."""
        reqs = [w.slots[j] for j in w.occupied()]
        reqs.extend(w.local)
        w.slots = [None] * w.s
        w.local.clear()
        w.state = None
        w.B_dev = None
        if w in self.workers:
            self.workers.remove(w)
        group = self._by_key.get(w.key)
        if group and w in group:
            group.remove(w)
            if not group:
                del self._by_key[w.key]
        ev = DeathEvent(tick=self.ticks, worker=w.wid,
                        req_ids=tuple(r.req_id for r in reqs),
                        reason=f"{type(exc).__name__}: {exc}")
        self.death_log.append(ev)
        deaths.append(ev)
        self._c_deaths.inc()
        return reqs

    def dispatch(self, req: SolveRequest) -> SlabWorker:
        """Route one admitted request to a worker (creating/replicating
        as needed); deterministic in the submission sequence."""
        group = self._by_key.get(req.slab_key)
        if not group:
            w = self._spawn(req.slab_key)
        else:
            w = min(group, key=lambda w: (w.load(), w.wid))
            if (len(group) < self.max_replicas
                    and w.backlog() >= self.replicate_watermark * w.s):
                w = self._spawn(req.slab_key)
        w.local.append(req)
        return w

    # ------------------------------------------------------------- tick --
    def _take_local(self, w: SlabWorker, k: int, now: float,
                    shed: list[SolveRequest]) -> list[SolveRequest]:
        """Pop up to k live requests from w's own queue head, shedding
        expired ones along the way."""
        out: list[SolveRequest] = []
        while len(out) < k and w.local:
            req = w.local.popleft()
            if self.shed_expired and req.expired(now):
                self.shed_log.append(ShedEvent(
                    tick=self.ticks, worker=w.wid, req_id=req.req_id,
                    t=now, waited_s=now - req.submitted_at))
                self._c_sheds.inc()
                shed.append(req)
                continue
            out.append(req)
        return out

    def _steal(self, w: SlabWorker, k: int, now: float,
               shed: list[SolveRequest]) -> list[SolveRequest]:
        """Steal up to k live requests from same-key siblings' tails,
        deepest backlog first (ties: lowest worker id)."""
        out: list[SolveRequest] = []
        siblings = [v for v in self._by_key[w.key] if v.wid != w.wid]
        while len(out) < k:
            victims = [v for v in siblings if v.backlog() > 0]
            if not victims:
                break
            v = min(victims, key=lambda v: (-v.backlog(), v.wid))
            req = v.local.pop()         # thief takes the TAIL
            if self.shed_expired and req.expired(now):
                self.shed_log.append(ShedEvent(
                    tick=self.ticks, worker=v.wid, req_id=req.req_id,
                    t=now, waited_s=now - req.submitted_at))
                self._c_sheds.inc()
                shed.append(req)
                continue
            self.steal_log.append(StealEvent(
                tick=self.ticks, thief=w.wid, victim=v.wid,
                req_id=req.req_id))
            self._c_steals.labels(thief=str(w.wid)).inc()
            out.append(req)
        return out

    def tick(self, now: float) -> TickReport:
        """One scheduler tick: pack every worker, chunk all busy slabs
        (dispatched back-to-back so independent slabs overlap on the
        device stream), then poll/retire.

        Each phase isolates worker faults (``WORKER_FAULT_TYPES``): a
        worker whose pack/chunk/poll raises is torn down via
        :meth:`_fail_worker` and its unretired requests come back in
        ``TickReport.failed`` — the surviving workers' tick proceeds
        untouched (self-healing serve, DESIGN.md §19)."""
        self.ticks += 1
        self._c_ticks.inc()
        shed: list[SolveRequest] = []
        failed: list[SolveRequest] = []
        deaths: list[DeathEvent] = []
        for w in list(self.workers):
            if not self.continuous and w.occupied():
                continue                # drain-to-empty baseline
            k = len(w.free_slots())
            incoming = self._take_local(w, k, now, shed)
            if self.steal and len(incoming) < k and not w.local:
                incoming += self._steal(w, k - len(incoming), now, shed)
            if incoming:
                try:
                    w.pack(incoming)
                except WORKER_FAULT_TYPES as e:
                    # pack places requests into slots before touching
                    # the program, so occupied() covers ``incoming``.
                    failed.extend(self._fail_worker(w, e, deaths))
        # Chunks dispatch back-to-back (each .chunk returns an async
        # handle-backed state), so independent slabs still overlap.
        live: list[SlabWorker] = []
        new_states = []
        for w in [w for w in self.workers if w.occupied()]:
            try:
                if self.fault_injector is not None:
                    self.fault_injector(self.ticks, w)
                new_states.append(w.program.chunk(w.B_dev, w.state))
                live.append(w)
            except WORKER_FAULT_TYPES as e:
                failed.extend(self._fail_worker(w, e, deaths))
        for w, st in zip(live, new_states):
            w.state = st
        self.chunks_run += len(live)
        self._c_chunks.inc(len(live))
        retired: list[RetiredColumn] = []
        for w in live:
            try:
                retired.extend(w.poll())
            except WORKER_FAULT_TYPES as e:
                # An async dispatch error surfaces at the poll's host
                # transfer — same teardown, minus whatever retired.
                failed.extend(self._fail_worker(w, e, deaths))
        return TickReport(retired=retired, shed=shed, chunks_run=len(live),
                          failed=failed, deaths=deaths)

    # -------------------------------------------------------- telemetry --
    def reset_stats(self) -> None:
        """Zero event logs, chunk/utilization accounting and the backing
        registry series (``ticks`` keeps counting: the retirement log's
        tick column must stay monotone across a stats reset)."""
        self.chunks_run = 0
        self.steal_log.clear()
        self.shed_log.clear()
        self.death_log.clear()
        self._c_steals.reset()
        self._c_sheds.reset()
        self._c_chunks.reset()
        self._c_deaths.reset()
        for w in self.workers:
            w.occupied_slot_iters = 0
            w.capacity_slot_iters = 0

    def backlog(self) -> int:
        return sum(w.backlog() for w in self.workers)

    def in_flight(self) -> int:
        return sum(len(w.occupied()) for w in self.workers)

    def slot_utilization(self) -> float:
        cap = sum(w.capacity_slot_iters for w in self.workers)
        if not cap:
            return 0.0
        occ = sum(w.occupied_slot_iters for w in self.workers)
        return occ / cap

    def replicas(self, key: SlabKey | Hashable = None) -> int:
        if key is None:
            return len(self.workers)
        return len(self._by_key.get(key, ()))
