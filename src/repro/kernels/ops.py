"""Jit'd public wrappers around the Pallas kernels.

Each wrapper (a) pads shapes to kernel-friendly multiples (zero padding is
exact for every kernel here), (b) picks TPU-aligned block shapes, and
(c) falls back to ``interpret=True`` off-TPU so the same call sites work on
this CPU container (system prompt: TPU is the TARGET, interpret mode is the
validation vehicle).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _da
from repro.kernels import ell_spmv as _el
from repro.kernels import fused_axpy as _fa
from repro.kernels import fused_dots as _fd
from repro.kernels import stencil_spmv as _ss


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@partial(jax.jit, static_argnames=("interpret",))
def stencil2d5_apply(g: jax.Array, interpret: bool | None = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    nx, ny = g.shape
    bx = 8
    while bx * 2 <= min(nx, 256) and nx % (bx * 2) == 0:
        bx *= 2
    nxp, nyp = _round_up(nx, bx), _round_up(ny, 128 if ny >= 128 else 8)
    gp = jnp.pad(g, ((0, nxp - nx), (0, nyp - ny)))
    out = _ss.stencil2d5(gp, block_x=bx, interpret=interpret)
    return out[:nx, :ny]


@partial(jax.jit, static_argnames=("eps_z", "interpret"))
def stencil3d7_apply(
    g: jax.Array, eps_z: float = 1.0, interpret: bool | None = None
) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    nx, ny, nz = g.shape
    bx = 8 if nx % 8 == 0 else (4 if nx % 4 == 0 else (2 if nx % 2 == 0 else 1))
    nzp = _round_up(nz, 128 if nz >= 128 else 8)
    gp = jnp.pad(g, ((0, 0), (0, 0), (0, nzp - nz)))
    out = _ss.stencil3d7(gp, eps_z=eps_z, block_x=bx, interpret=interpret)
    return out[:, :, :nz]


@partial(jax.jit, static_argnames=("interpret",))
def ell_spmv_apply(x: jax.Array, cols: jax.Array, vals: jax.Array,
                   interpret: bool | None = None) -> jax.Array:
    """Unstructured padded-row ELL SpMV (DESIGN.md §12).

    ``x`` may be longer than the row count (the distributed path passes
    the halo-extended local vector).  Rows are padded to a block multiple
    with zero-value slots — exact, since padded rows are sliced off."""
    interpret = _interpret_default() if interpret is None else interpret
    r, w = cols.shape
    br = 8
    while br * 2 <= min(r, 256) and r % (br * 2) == 0:
        br *= 2
    rp = _round_up(r, br)
    colsp = jnp.pad(cols, ((0, rp - r), (0, 0)))
    valsp = jnp.pad(vals, ((0, rp - r), (0, 0)))
    out = _el.ell_spmv(x, colsp, valsp, block_r=br, interpret=interpret)
    return out[:r]


@partial(jax.jit, static_argnames=("interpret",))
def fused_dots(mat: jax.Array, vec: jax.Array, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    k, n = mat.shape
    bn = min(16384, _round_up(n, 128))
    npad = _round_up(n, bn)
    matp = jnp.pad(mat, ((0, 0), (0, npad - n)))
    vecp = jnp.pad(vec, (0, npad - n))
    return _fd.fused_dots(matp, vecp, block_n=bn, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def fused_dots_mrhs(mat: jax.Array, vecs: jax.Array,
                    interpret: bool | None = None):
    """(K, N) x (N, S) -> (K, S): the slab dot block, mat streamed once for
    all S right-hand sides (DESIGN.md §11).  Zero-pads N to a block
    multiple and S to the TPU lane width off-interpret."""
    interpret = _interpret_default() if interpret is None else interpret
    k, n = mat.shape
    s = vecs.shape[1]
    bn = min(16384, _round_up(n, 128))
    npad = _round_up(n, bn)
    spad = _round_up(s, 8 if interpret else 128)
    matp = jnp.pad(mat, ((0, 0), (0, npad - n)))
    vecsp = jnp.pad(vecs, ((0, npad - n), (0, spad - s)))
    out = _fd.fused_dots_mrhs(matp, vecsp, block_n=bn, interpret=interpret)
    return out[:, :s]


@partial(jax.jit, static_argnames=("interpret",))
def fused_axpy3(zk1, zm1, zm2, c1, c2, scale, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    (n,) = zk1.shape
    bn = min(65536, _round_up(n, 128))
    npad = _round_up(n, bn)
    pad = lambda v: jnp.pad(v, (0, npad - n))
    out = _fa.fused_axpy3(
        pad(zk1), pad(zm1), pad(zm2), c1, c2, scale, block_n=bn,
        interpret=interpret,
    )
    return out[:n]


@partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(
    q: jax.Array,       # (B, H, D)
    k: jax.Array,       # (B, S, Hkv, D)
    v: jax.Array,       # (B, S, Hkv, D)
    kv_len: jax.Array | int,
    block_s: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Single-token GQA decode attention over a (possibly padded) KV cache.
    Returns (B, H, D) in q.dtype."""
    interpret = _interpret_default() if interpret is None else interpret
    b, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    sp = _round_up(s, block_s)
    kp = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    qg = q.reshape(b, hkv, g, d)
    kt = jnp.transpose(kp, (0, 2, 1, 3))     # (B, Hkv, S, D)
    vt = jnp.transpose(vp, (0, 2, 1, 3))
    ln = jnp.full((1, 1), kv_len, jnp.int32)
    o, m, l = _da.decode_attention_stats(
        qg, kt, vt, ln, block_s=block_s, interpret=interpret
    )
    out = o / jnp.maximum(l, 1e-30)
    return out.reshape(b, h, d).astype(q.dtype)


def decode_attention_stats(q, k, v, kv_len, block_s: int = 512, interpret=None):
    """Unnormalized (o, m, l) for cross-shard split-KV combine."""
    interpret = _interpret_default() if interpret is None else interpret
    b, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    sp = _round_up(s, block_s)
    kp = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    qg = q.reshape(b, hkv, g, d)
    kt = jnp.transpose(kp, (0, 2, 1, 3))
    vt = jnp.transpose(vp, (0, 2, 1, 3))
    ln = jnp.full((1, 1), kv_len, jnp.int32)
    return _da.decode_attention_stats(
        qg, kt, vt, ln, block_s=block_s, interpret=interpret
    )
