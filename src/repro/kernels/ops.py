"""Jit'd public wrappers around the Pallas kernels.

Each wrapper (a) pads shapes to kernel-friendly multiples (zero padding is
exact for every kernel here), (b) picks TPU-aligned block shapes, and
(c) falls back to ``interpret=True`` off-TPU so the same call sites work on
this CPU container (system prompt: TPU is the TARGET, interpret mode is the
validation vehicle).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _da
from repro.kernels import ell_spmv as _el
from repro.kernels import fused_axpy as _fa
from repro.kernels import fused_dots as _fd
from repro.kernels import fused_iter as _fi
from repro.kernels import stencil_spmv as _ss


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ------------------------------------------------- fused-iteration factory --

def _local_fused_spmv(op):
    """Single-device :class:`~repro.kernels.fused_iter.FusedSpmv` for the
    operator, mirroring its pure-jnp ``apply`` expression term by term
    (the bitwise contract of the superkernel); None when unsupported."""
    from repro.linalg.operators import (DiagonalOp, Stencil2D5, Stencil3D7,
                                        Stencil3D27)
    from repro.linalg.sparse import SparseOp

    if isinstance(op, DiagonalOp):
        return _fi.diagonal_spmv(op.d)
    if getattr(op, "use_kernel", False):
        # use_kernel operators route the unfused path through the
        # standalone Pallas kernels (whose reductions round differently
        # from the jnp expressions this kernel mirrors); the superkernel
        # subsumes those, but mirroring a kernel inside a kernel is not
        # a thing — no fused path, the solver fails loudly.
        return None
    if isinstance(op, SparseOp):
        return _fi.ell_spmv(op.cols, op.vals, lambda z: z, op.n)
    if isinstance(op, Stencil2D5):
        nx, ny = op.nx, op.ny

        def expr2d(z):
            g = z.reshape(nx, ny)
            p = jnp.pad(g, 1)
            out = (4.0 * g - p[:-2, 1:-1] - p[2:, 1:-1]
                   - p[1:-1, :-2] - p[1:-1, 2:])
            return out.reshape(-1)

        return _fi.resident_spmv(expr2d, lambda z: z, op.n)
    if isinstance(op, Stencil3D7):
        nx, ny, nz, eps_z = op.nx, op.ny, op.nz, op.eps_z

        def expr3d(z):
            g = z.reshape(nx, ny, nz)
            p = jnp.pad(g, 1)
            ez = jnp.asarray(eps_z, dtype=z.dtype)
            out = (
                (4.0 + 2.0 * ez) * g
                - p[:-2, 1:-1, 1:-1] - p[2:, 1:-1, 1:-1]
                - p[1:-1, :-2, 1:-1] - p[1:-1, 2:, 1:-1]
                - ez * p[1:-1, 1:-1, :-2] - ez * p[1:-1, 1:-1, 2:]
            )
            return out.reshape(-1)

        return _fi.resident_spmv(expr3d, lambda z: z, op.n)
    if isinstance(op, Stencil3D27):
        nx, ny, nz, centre = op.nx, op.ny, op.nz, op.centre

        def expr27(z):
            g = z.reshape(nx, ny, nz)
            p = jnp.pad(g, 1)
            out = centre * g
            for di in (-1, 0, 1):
                for dj in (-1, 0, 1):
                    for dk in (-1, 0, 1):
                        order = abs(di) + abs(dj) + abs(dk)
                        if order == 0:
                            continue
                        w = {1: 1.0, 2: 0.5, 3: 0.25}[order]
                        out = out - w * p[1 + di:1 + di + nx,
                                          1 + dj:1 + dj + ny,
                                          1 + dk:1 + dk + nz]
            return out.reshape(-1)

        return _fi.resident_spmv(expr27, lambda z: z, op.n)
    return None


def fused_iteration_factory(op, prec=None):
    """Factory for the fused-iteration superkernel on the LOCAL substrate
    (DESIGN.md §13), or None when the (operator, preconditioner) pair has
    no fused path — unsupported operator kinds, kernel-routed stencils,
    or non-pointwise (block-structured) preconditioners.

    The returned ``factory(layout, interpret=None, block_n=None)`` builds
    the per-iteration vector-phase callable consumed by
    ``pipelined_cg.build(..., fused_iteration=True)``.
    """
    from repro.linalg.preconditioners import IdentityPrec, JacobiPrec

    if prec is None or isinstance(prec, IdentityPrec):
        inv_diag = None
    elif isinstance(prec, JacobiPrec):
        inv_diag = prec.inv_diag
    else:
        return None
    spmv = _local_fused_spmv(op)
    if spmv is None:
        return None

    def factory(layout, interpret: bool | None = None,
                block_n: int | None = None):
        interp = _interpret_default() if interpret is None else interpret
        return _fi.build_fused_iteration(layout, spmv, inv_diag,
                                         block_n=block_n, interpret=interp)

    return factory


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@partial(jax.jit, static_argnames=("interpret",))
def stencil2d5_apply(g: jax.Array, interpret: bool | None = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    nx, ny = g.shape
    bx = 8
    while bx * 2 <= min(nx, 256) and nx % (bx * 2) == 0:
        bx *= 2
    nxp, nyp = _round_up(nx, bx), _round_up(ny, 128 if ny >= 128 else 8)
    gp = jnp.pad(g, ((0, nxp - nx), (0, nyp - ny)))
    out = _ss.stencil2d5(gp, block_x=bx, interpret=interpret)
    return out[:nx, :ny]


@partial(jax.jit, static_argnames=("eps_z", "interpret"))
def stencil3d7_apply(
    g: jax.Array, eps_z: float = 1.0, interpret: bool | None = None
) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    nx, ny, nz = g.shape
    bx = 8 if nx % 8 == 0 else (4 if nx % 4 == 0 else (2 if nx % 2 == 0 else 1))
    nzp = _round_up(nz, 128 if nz >= 128 else 8)
    gp = jnp.pad(g, ((0, 0), (0, 0), (0, nzp - nz)))
    out = _ss.stencil3d7(gp, eps_z=eps_z, block_x=bx, interpret=interpret)
    return out[:, :, :nz]


@partial(jax.jit, static_argnames=("interpret",))
def ell_spmv_apply(x: jax.Array, cols: jax.Array, vals: jax.Array,
                   interpret: bool | None = None) -> jax.Array:
    """Unstructured padded-row ELL SpMV (DESIGN.md §12).

    ``x`` may be longer than the row count (the distributed path passes
    the halo-extended local vector).  Rows are padded to a block multiple
    with zero-value slots — exact, since padded rows are sliced off."""
    interpret = _interpret_default() if interpret is None else interpret
    r, w = cols.shape
    br = 8
    while br * 2 <= min(r, 256) and r % (br * 2) == 0:
        br *= 2
    rp = _round_up(r, br)
    colsp = jnp.pad(cols, ((0, rp - r), (0, 0)))
    valsp = jnp.pad(vals, ((0, rp - r), (0, 0)))
    out = _el.ell_spmv(x, colsp, valsp, block_r=br, interpret=interpret)
    return out[:r]


@partial(jax.jit, static_argnames=("interpret",))
def fused_dots(mat: jax.Array, vec: jax.Array, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    k, n = mat.shape
    bn = min(16384, _round_up(n, 128))
    npad = _round_up(n, bn)
    matp = jnp.pad(mat, ((0, 0), (0, npad - n)))
    vecp = jnp.pad(vec, (0, npad - n))
    return _fd.fused_dots(matp, vecp, block_n=bn, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def fused_dots_mrhs(mat: jax.Array, vecs: jax.Array,
                    interpret: bool | None = None):
    """(K, N) x (N, S) -> (K, S): the slab dot block, mat streamed once for
    all S right-hand sides (DESIGN.md §11).  Zero-pads N to a block
    multiple and S to the TPU lane width off-interpret."""
    interpret = _interpret_default() if interpret is None else interpret
    k, n = mat.shape
    s = vecs.shape[1]
    bn = min(16384, _round_up(n, 128))
    npad = _round_up(n, bn)
    spad = _round_up(s, 8 if interpret else 128)
    matp = jnp.pad(mat, ((0, 0), (0, npad - n)))
    vecsp = jnp.pad(vecs, ((0, npad - n), (0, spad - s)))
    out = _fd.fused_dots_mrhs(matp, vecsp, block_n=bn, interpret=interpret)
    return out[:, :s]


@partial(jax.jit, static_argnames=("interpret",))
def fused_axpy3(zk1, zm1, zm2, c1, c2, scale, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    (n,) = zk1.shape
    bn = min(65536, _round_up(n, 128))
    npad = _round_up(n, bn)
    pad = lambda v: jnp.pad(v, (0, npad - n))
    out = _fa.fused_axpy3(
        pad(zk1), pad(zm1), pad(zm2), c1, c2, scale, block_n=bn,
        interpret=interpret,
    )
    return out[:n]


@partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(
    q: jax.Array,       # (B, H, D)
    k: jax.Array,       # (B, S, Hkv, D)
    v: jax.Array,       # (B, S, Hkv, D)
    kv_len: jax.Array | int,
    block_s: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Single-token GQA decode attention over a (possibly padded) KV cache.
    Returns (B, H, D) in q.dtype."""
    interpret = _interpret_default() if interpret is None else interpret
    b, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    sp = _round_up(s, block_s)
    kp = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    qg = q.reshape(b, hkv, g, d)
    kt = jnp.transpose(kp, (0, 2, 1, 3))     # (B, Hkv, S, D)
    vt = jnp.transpose(vp, (0, 2, 1, 3))
    ln = jnp.full((1, 1), kv_len, jnp.int32)
    o, m, l = _da.decode_attention_stats(
        qg, kt, vt, ln, block_s=block_s, interpret=interpret
    )
    out = o / jnp.maximum(l, 1e-30)
    return out.reshape(b, h, d).astype(q.dtype)


def decode_attention_stats(q, k, v, kv_len, block_s: int = 512, interpret=None):
    """Unnormalized (o, m, l) for cross-shard split-KV combine."""
    interpret = _interpret_default() if interpret is None else interpret
    b, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    sp = _round_up(s, block_s)
    kp = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    qg = q.reshape(b, hkv, g, d)
    kt = jnp.transpose(kp, (0, 2, 1, 3))
    vt = jnp.transpose(vp, (0, 2, 1, 3))
    ln = jnp.full((1, 1), kv_len, jnp.int32)
    return _da.decode_attention_stats(
        qg, kt, vt, ln, block_s=block_s, interpret=interpret
    )
