"""Pallas split-KV decode attention (flash-decoding style) for the serving
path (decode_32k / long_500k shapes).

One new query token attends to a long KV cache.  The cache's sequence axis
is blocked; an online-softmax accumulator (m, l, acc) lives in VMEM scratch
and is carried across the sequence grid dimension.  GQA is handled by
processing one KV head per grid cell with its G = H/H_kv query heads.

Cross-device split-KV (cache sharded over "model") happens OUTSIDE the
kernel: with ``return_stats=True`` the kernel emits the *unnormalized*
accumulator plus (m, l); the serve layer merges shards with one pmax + one
fused psum of O(H·D) — never O(S) — traffic (DESIGN.md §8).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _decode_attn_kernel(
    scale, block_s, q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref,
    acc_ref, mm_ref, ll_ref,
):
    s_idx = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        mm_ref[...] = jnp.full_like(mm_ref, _NEG_INF)
        ll_ref[...] = jnp.zeros_like(ll_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (BS, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (BS, D)
    kv_len = len_ref[0, 0]

    scores = (q @ k.T) * scale                   # (G, BS)
    col = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(col < kv_len, scores, _NEG_INF)

    m_prev = mm_ref[...]                         # (G, 1)
    m_cur = jnp.max(scores, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(scores - m_new)                  # (G, BS)
    alpha = jnp.exp(m_prev - m_new)              # (G, 1)
    ll_ref[...] = ll_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    mm_ref[...] = m_new

    @pl.when(s_idx == ns - 1)
    def _fin():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)   # UNNORMALIZED
        m_ref[0, 0] = mm_ref[...]
        l_ref[0, 0] = ll_ref[...]


def decode_attention_stats(
    q: jax.Array,        # (B, Hkv, G, D)  — grouped query heads
    k: jax.Array,        # (B, Hkv, S, D)
    v: jax.Array,        # (B, Hkv, S, D)
    kv_len: jax.Array,   # (1, 1) int32 — valid cache length (masking)
    *,
    block_s: int = 512,
    interpret: bool = False,
):
    """Returns (o_unnorm (B,Hkv,G,D) f32, m (B,Hkv,G,1) f32, l (B,Hkv,G,1) f32).

    Final attention = o_unnorm / l; with sharded KV, merge stats across
    shards first (see repro.models.attention.merge_decode_shards).
    """
    b, hkv, g, d = q.shape
    _, _, s, _ = k.shape
    assert s % block_s == 0, (s, block_s)
    ns = s // block_s
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_decode_attn_kernel, scale, block_s)
    from jax.experimental.pallas import tpu as pltpu

    o, m, l = pl.pallas_call(
        kernel,
        grid=(b, hkv, ns),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda ib, ih, isq: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, block_s, d), lambda ib, ih, isq: (ib, ih, isq, 0)),
            pl.BlockSpec((1, 1, block_s, d), lambda ib, ih, isq: (ib, ih, isq, 0)),
            pl.BlockSpec((1, 1), lambda ib, ih, isq: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda ib, ih, isq: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda ib, ih, isq: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda ib, ih, isq: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, kv_len)
    return o, m, l
