"""Pallas kernel for the padded-row (ELL) unstructured SpMV
(DESIGN.md §12) — the irregular counterpart of ``stencil_spmv``.

Storage is dense-rectangular: ``cols``/``vals`` are (R, W) with W =
max-nnz-per-row and zero-valued padding, so every load is a contiguous
(BR, W) tile — CSR's ragged row pointers never reach the kernel.  The
irregularity is confined to ONE gather per tile: ``x[cols_tile]``, with
``x`` held resident in VMEM for the whole grid (the per-shard vectors of
the solver path are a few MB — domain decomposition already bounded
them).  After the gather the reduction is a dense (BR, W) multiply +
small-axis sum on the VPU.

The gather is the TPU cost center: Mosaic lowers it to dynamic VMEM
loads, which is why the wrapper (ops.py) keeps rows RCM-ordered — the
partitioner's bandwidth reduction (``repro.linalg.partition``) makes
consecutive rows hit near-consecutive x slots, the gather-locality
equivalent of the stencil kernel's contiguous halo planes.  Off-TPU the
kernel runs in interpret mode (the repo-wide validation vehicle); the
pure-jnp oracle is ``kernels.ref.ell_spmv_ref``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ell_spmv_kernel(x_ref, cols_ref, vals_ref, o_ref):
    x = x_ref[...]                          # (NX,) resident vector
    cols = cols_ref[...]                    # (BR, W) int32
    vals = vals_ref[...]                    # (BR, W)
    gathered = x[cols]                      # the one irregular access
    o_ref[...] = (vals * gathered.astype(vals.dtype)).sum(axis=1)


def ell_spmv(
    x: jax.Array,
    cols: jax.Array,
    vals: jax.Array,
    *,
    block_r: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """y[r] = sum_s vals[r, s] * x[cols[r, s]] over (R, W) ELL slots.

    ``R`` must be a multiple of ``block_r`` (ops.py pads with zero-value
    rows, exact by construction).  ``x`` may be LONGER than R — the
    distributed path passes the extended local vector [own | halo]
    (``repro.linalg.partition.apply_local``).
    """
    r, w = cols.shape
    assert vals.shape == (r, w), (vals.shape, cols.shape)
    assert r % block_r == 0, (r, block_r)
    nb = r // block_r
    nx = x.shape[0]
    return pl.pallas_call(
        _ell_spmv_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((nx,), lambda i: (0,)),          # x resident
            pl.BlockSpec((block_r, w), lambda i: (i, 0)),
            pl.BlockSpec((block_r, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), vals.dtype),
        interpret=interpret,
    )(x, cols, vals)
