"""Pallas kernel for the fused dot-product block — the paper's (K5).

p(l)-CG computes 2l+1 (sym-optimized: l+1) inner products against ONE shared
operand u per iteration (Alg. 1 line 23).  Done naively that is 2l+1 full
HBM passes over u plus one over each basis vector; fused, u is streamed ONCE
and every basis row is read once: arithmetic intensity rises from ~1/8 to
~(K)/(K+1) flop/byte — this kernel makes the local dot contribution
bandwidth-optimal before the single psum.

Layout: mat (K, N) row-major (the K basis vectors), vec (N,).  Grid over N
in blocks; a (K, 1) f32 accumulator output block is revisited by every grid
step (index_map -> (0, 0)), relying on TPU's sequential grid execution.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_dots_kernel(mat_ref, vec_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    m = mat_ref[...].astype(jnp.float32)      # (K, BN)
    v = vec_ref[...].astype(jnp.float32)      # (BN, 1)
    o_ref[...] += m @ v


def fused_dots(
    mat: jax.Array, vec: jax.Array, *, block_n: int = 16384, interpret: bool = False
) -> jax.Array:
    """All K inner products mat @ vec in one HBM pass.  N must be a multiple
    of block_n (ops.py pads with zeros, which do not change the result)."""
    k, n = mat.shape
    assert vec.shape == (n,)
    assert n % block_n == 0, (n, block_n)
    nb = n // block_n
    out = pl.pallas_call(
        _fused_dots_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.float32),
        interpret=interpret,
    )(mat, vec[:, None])
    return out[:, 0].astype(mat.dtype)
