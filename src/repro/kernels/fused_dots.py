"""Pallas kernel for the fused dot-product block — the paper's (K5).

p(l)-CG computes 2l+1 (sym-optimized: l+1) inner products against ONE shared
operand u per iteration (Alg. 1 line 23).  Done naively that is 2l+1 full
HBM passes over u plus one over each basis vector; fused, u is streamed ONCE
and every basis row is read once: arithmetic intensity rises from ~1/8 to
~(K)/(K+1) flop/byte — this kernel makes the local dot contribution
bandwidth-optimal before the single psum.

Layout: mat (K, N) row-major (the K basis vectors), vec (N,).  Grid over N
in blocks; a (K, 1) f32 accumulator output block is revisited by every grid
step (index_map -> (0, 0)), relying on TPU's sequential grid execution.

Multi-RHS variant (``fused_dots_mrhs``, the serving layer's dot block,
DESIGN.md §11): the same streaming structure against S right-hand-side
columns at once — mat is streamed ONCE for all S columns and the (K, S)
accumulator block becomes the local half of the slab's single amortized
allreduce payload.  S = 1 recovers the single-RHS kernel exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_dots_kernel(mat_ref, vec_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    m = mat_ref[...].astype(jnp.float32)      # (K, BN)
    v = vec_ref[...].astype(jnp.float32)      # (BN, S)
    o_ref[...] += m @ v


def fused_dots_mrhs(
    mat: jax.Array, vecs: jax.Array, *, block_n: int = 16384,
    interpret: bool = False
) -> jax.Array:
    """All K*S inner products mat @ vecs in one HBM pass over ``mat``.

    mat (K, N), vecs (N, S) -> (K, S).  N must be a multiple of block_n
    (ops.py pads with zeros, which do not change the result); on real TPU
    S should be lane-aligned (ops.py pads).
    """
    k, n = mat.shape
    assert vecs.ndim == 2 and vecs.shape[0] == n, (mat.shape, vecs.shape)
    s = vecs.shape[1]
    assert n % block_n == 0, (n, block_n)
    nb = n // block_n
    out = pl.pallas_call(
        _fused_dots_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
            pl.BlockSpec((block_n, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k, s), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, s), jnp.float32),
        interpret=interpret,
    )(mat, vecs)
    return out.astype(mat.dtype)


def fused_dots(
    mat: jax.Array, vec: jax.Array, *, block_n: int = 16384, interpret: bool = False
) -> jax.Array:
    """All K inner products mat @ vec in one HBM pass.  N must be a multiple
    of block_n (ops.py pads with zeros, which do not change the result)."""
    k, n = mat.shape
    assert vec.shape == (n,)
    out = fused_dots_mrhs(mat, vec[:, None], block_n=block_n,
                          interpret=interpret)
    return out[:, 0]
