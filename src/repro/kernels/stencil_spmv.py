"""Pallas TPU kernels for stencil SPMV — the paper's (K1) hot spot.

TPU-native rethink of the PETSc CSR SpMV (DESIGN.md §8): the benchmark
matrices are stencils, so instead of gather-bound CSR we tile the *grid*
into VMEM row blocks.  Each program instance loads a contiguous
(BX, ny[, nz]) tile plus two one-row/one-plane halo refs prepared by the
wrapper — every load is contiguous and (8,128)-tileable, no gathers.

Block-shape guidance (ops.py enforces): BX multiple of 8, trailing dim
padded to a multiple of 128.  VMEM footprint per program:
  2D : (BX+2+3·BX) · ny · 4 B   — g tile, 2 halo rows, out
  3D : ~5 · BX · ny · nz · 4 B
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------- 2D 5-pt --

def _stencil2d5_kernel(g_ref, up_ref, dn_ref, o_ref):
    g = g_ref[...]                       # (BX, ny)
    gx = jnp.concatenate([up_ref[...], g, dn_ref[...]], axis=0)   # (BX+2, ny)
    left = jnp.pad(g[:, :-1], ((0, 0), (1, 0)))    # neighbour j-1
    right = jnp.pad(g[:, 1:], ((0, 0), (0, 1)))    # neighbour j+1
    o_ref[...] = 4.0 * g - gx[:-2] - gx[2:] - left - right


def stencil2d5(g: jax.Array, *, block_x: int = 256, interpret: bool = False):
    """5-point Laplacian on an (nx, ny) grid, homogeneous Dirichlet BCs.

    The wrapper (ops.py) guarantees nx % block_x == 0; halo rows for block i
    are the last row of block i-1 and the first row of block i+1 (zeros at
    the domain boundary).
    """
    nx, ny = g.shape
    assert nx % block_x == 0, (nx, block_x)
    nb = nx // block_x
    gb = g.reshape(nb, block_x, ny)
    zrow = jnp.zeros((1, ny), g.dtype)
    up = jnp.concatenate([zrow, gb[:-1, -1, :]], axis=0)     # (nb, ny)
    dn = jnp.concatenate([gb[1:, 0, :], zrow], axis=0)       # (nb, ny)

    return pl.pallas_call(
        _stencil2d5_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_x, ny), lambda i: (i, 0)),
            pl.BlockSpec((1, ny), lambda i: (i, 0)),
            pl.BlockSpec((1, ny), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_x, ny), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nx, ny), g.dtype),
        interpret=interpret,
    )(g, up, dn)


# ---------------------------------------------------------------- 3D 7-pt --

def _stencil3d7_kernel(eps_z, g_ref, up_ref, dn_ref, o_ref):
    g = g_ref[...]                       # (BX, ny, nz)
    gx = jnp.concatenate([up_ref[...], g, dn_ref[...]], axis=0)
    gy1 = jnp.pad(g[:, :-1, :], ((0, 0), (1, 0), (0, 0)))
    gy2 = jnp.pad(g[:, 1:, :], ((0, 0), (0, 1), (0, 0)))
    gz1 = jnp.pad(g[:, :, :-1], ((0, 0), (0, 0), (1, 0)))
    gz2 = jnp.pad(g[:, :, 1:], ((0, 0), (0, 0), (0, 1)))
    ez = jnp.asarray(eps_z, g.dtype)
    o_ref[...] = (
        (4.0 + 2.0 * ez) * g - gx[:-2] - gx[2:] - gy1 - gy2 - ez * gz1 - ez * gz2
    )


def stencil3d7(
    g: jax.Array, eps_z: float = 1.0, *, block_x: int = 8, interpret: bool = False
):
    """Anisotropic 7-point Laplacian on an (nx, ny, nz) grid (Dirichlet)."""
    nx, ny, nz = g.shape
    assert nx % block_x == 0, (nx, block_x)
    nb = nx // block_x
    gb = g.reshape(nb, block_x, ny, nz)
    zpl = jnp.zeros((1, ny, nz), g.dtype)
    up = jnp.concatenate([zpl, gb[:-1, -1]], axis=0)         # (nb, ny, nz)
    dn = jnp.concatenate([gb[1:, 0], zpl], axis=0)

    return pl.pallas_call(
        functools.partial(_stencil3d7_kernel, eps_z),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_x, ny, nz), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, ny, nz), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, ny, nz), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_x, ny, nz), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nx, ny, nz), g.dtype),
        interpret=interpret,
    )(g, up, dn)
