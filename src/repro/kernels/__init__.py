"""Pallas TPU kernels for the compute hot spots (ops.py = public wrappers,
ref.py = pure-jnp oracles, one module per kernel).  ``fused_iter`` is
the whole-iteration superkernel for p(l)-CG (DESIGN.md §13)."""

from repro.kernels import fused_iter, ops, ref

__all__ = ["fused_iter", "ops", "ref"]
