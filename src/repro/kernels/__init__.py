"""Pallas TPU kernels for the compute hot spots (ops.py = public wrappers,
ref.py = pure-jnp oracles, one module per kernel)."""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
