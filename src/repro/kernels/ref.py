"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def stencil2d5_ref(g: jax.Array) -> jax.Array:
    p = jnp.pad(g, 1)
    return 4.0 * g - p[:-2, 1:-1] - p[2:, 1:-1] - p[1:-1, :-2] - p[1:-1, 2:]


def stencil3d7_ref(g: jax.Array, eps_z: float = 1.0) -> jax.Array:
    p = jnp.pad(g, 1)
    ez = jnp.asarray(eps_z, g.dtype)
    return (
        (4.0 + 2.0 * ez) * g
        - p[:-2, 1:-1, 1:-1] - p[2:, 1:-1, 1:-1]
        - p[1:-1, :-2, 1:-1] - p[1:-1, 2:, 1:-1]
        - ez * p[1:-1, 1:-1, :-2] - ez * p[1:-1, 1:-1, 2:]
    )


def ell_spmv_ref(x: jax.Array, cols: jax.Array, vals: jax.Array) -> jax.Array:
    """Padded-row ELL SpMV: y[r] = sum_s vals[r,s] * x[cols[r,s]].
    Padded slots carry vals 0 (their gathered x value is irrelevant)."""
    return (vals * x[cols].astype(vals.dtype)).sum(axis=1)


def fused_dots_ref(mat: jax.Array, vec: jax.Array) -> jax.Array:
    return (mat.astype(jnp.float32) @ vec.astype(jnp.float32)).astype(mat.dtype)


def fused_axpy3_ref(zk1, zm1, zm2, c1, c2, scale):
    out = (
        zk1.astype(jnp.float32)
        + jnp.float32(c1) * zm1.astype(jnp.float32)
        + jnp.float32(c2) * zm2.astype(jnp.float32)
    ) * jnp.float32(scale)
    return out.astype(zk1.dtype)


def decode_attention_ref(q, k, v, kv_len):
    """q (B,Hkv,G,D), k/v (B,Hkv,S,D), kv_len scalar int -> (B,Hkv,G,D) f32.

    Normalized output (the oracle for o_unnorm / l)."""
    b, hkv, g, d = q.shape
    s = k.shape[2]
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum(
        "bhgd,bhsd->bhgs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    mask = jnp.arange(s)[None, None, None, :] < kv_len
    scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", w, v.astype(jnp.float32))
