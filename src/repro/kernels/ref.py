"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def stencil2d5_ref(g: jax.Array) -> jax.Array:
    p = jnp.pad(g, 1)
    return 4.0 * g - p[:-2, 1:-1] - p[2:, 1:-1] - p[1:-1, :-2] - p[1:-1, 2:]


def stencil3d7_ref(g: jax.Array, eps_z: float = 1.0) -> jax.Array:
    p = jnp.pad(g, 1)
    ez = jnp.asarray(eps_z, g.dtype)
    return (
        (4.0 + 2.0 * ez) * g
        - p[:-2, 1:-1, 1:-1] - p[2:, 1:-1, 1:-1]
        - p[1:-1, :-2, 1:-1] - p[1:-1, 2:, 1:-1]
        - ez * p[1:-1, 1:-1, :-2] - ez * p[1:-1, 1:-1, 2:]
    )


def ell_spmv_ref(x: jax.Array, cols: jax.Array, vals: jax.Array) -> jax.Array:
    """Padded-row ELL SpMV: y[r] = sum_s vals[r,s] * x[cols[r,s]].
    Padded slots carry vals 0 (their gathered x value is irrelevant)."""
    return (vals * x[cols].astype(vals.dtype)).sum(axis=1)


def fused_dots_ref(mat: jax.Array, vec: jax.Array) -> jax.Array:
    return (mat.astype(jnp.float32) @ vec.astype(jnp.float32)).astype(mat.dtype)


def fused_axpy3_ref(zk1, zm1, zm2, c1, c2, scale):
    out = (
        zk1.astype(jnp.float32)
        + jnp.float32(c1) * zm1.astype(jnp.float32)
        + jnp.float32(c2) * zm2.astype(jnp.float32)
    ) * jnp.float32(scale)
    return out.astype(zk1.dtype)


def fused_iter_unfused(S, idx, scal, apply_a, prec, layout):
    """UNFUSED p(l)-CG vector phase — the memory-bound reference path the
    superkernel replaces (DESIGN.md §13): one separate jnp op per SPMV /
    preconditioner / fill copy / recurrence AXPY / solution update, each
    re-reading the (NV, N) slab.  Returns ``(S', mat, u_new)`` with the
    dot-block OPERANDS left unreduced so the caller issues the reduction
    through its backend (``ops.start``); :func:`fused_iter_ref` closes
    them into local partials for kernel-level comparison.

    This function is also the production unfused path of
    ``repro.core.pipelined_cg`` — solver-level fused/unfused parity
    reduces to kernel-level parity against THESE expressions, which the
    kernel mirrors term by term (tests/test_fused_iter.py).
    """
    from repro.kernels.fused_iter import idx_layout, scal_layout

    l = layout.l
    IX = idx_layout(l)
    IS = scal_layout(l)

    def get(row):
        return jax.lax.dynamic_index_in_dim(S, row, 0, keepdims=False)

    def put(out, row, vec):
        return jax.lax.dynamic_update_index_in_dim(out, vec, row, axis=0)

    late = idx[IX["f_late"]] != 0
    z_top = get(idx[IX["z_top"]])
    u_i = get(idx[IX["u_i"]])
    u_im1 = get(idx[IX["u_im1"]])

    az = apply_a(z_top)
    u_new0 = az - scal[IS["sig_i"]] * u_i
    u_new = jnp.where(
        late,
        (u_new0 - scal[IS["gam_new"]] * u_i
         - scal[IS["d2"]] * u_im1) / scal[IS["dlt_safe"]],
        u_new0)
    if layout.recurrence == "stable":
        # Coupled recurrence (arXiv:1902.03100, DESIGN.md §18): recompute
        # the top basis vector as M^{-1} u_{i+1} from the recurred u
        # instead of recurring z independently.  Early iterations are
        # bitwise-unchanged (u_new == u_new0 there).
        z_new = prec(u_new)
        z_fill = z_new
    else:
        z_new0 = prec(u_new0)
        zl_im1 = get(idx[IX["zl_im1"]])
        z_new = jnp.where(
            late,
            (z_new0 - scal[IS["gam_new"]] * z_top
             - scal[IS["d2"]] * zl_im1) / scal[IS["dlt_safe"]],
            z_new0)
        z_fill = z_new0

    out = S
    for k in range(l):
        row = idx[IX["fill"] + k]
        fill_k = idx[IX["f_fill"] + k] != 0
        out = put(out, row, jnp.where(fill_k, z_fill, get(row)))

    recs = []
    for k in range(l):
        zk1 = get(idx[IX["rec_a"] + k])
        zm1 = get(idx[IX["rec_b"] + k])
        zm2 = get(idx[IX["rec_c"] + k])
        rec = (zk1 + scal[IS["c1"] + k] * zm1
               - scal[IS["d2"]] * zm2) / scal[IS["dlt_safe"]]
        val = jnp.where(late, rec, get(idx[IX["rec_w"] + k]))
        recs.append(val)
        out = put(out, idx[IX["rec_w"] + k], val)

    out = put(out, idx[IX["z_w"]], z_new)
    out = put(out, idx[IX["u_w"]], u_new)

    rows = [get(idx[IX["mat_v"] + t]) for t in range(l)] + [recs[0]]
    rows += [get(idx[IX["mat_z"] + t]) for t in range(l - 1)] + [z_new]
    mat = jnp.stack(rows)

    x_old = S[layout.x_row]
    p_old = S[layout.p_row]
    p_first = S[0] / scal[IS["eta0_safe"]]
    p_new = (get(idx[IX["p_im"]])
             - scal[IS["d_prev"]] * p_old) / scal[IS["eta_new_safe"]]
    x_new = x_old + scal[IS["zet_prev"]] * p_old
    do_upd = idx[IX["f_upd"]] != 0
    is_first = idx[IX["f_first"]] != 0
    out = out.at[layout.x_row].set(jnp.where(do_upd, x_new, x_old))
    out = out.at[layout.p_row].set(
        jnp.where(is_first, p_first, jnp.where(do_upd, p_new, p_old)))
    return out, mat, u_new


def fused_iter_ref(S, idx, scal, apply_a, prec, layout):
    """Unfused oracle with the local dot partials closed: the allclose /
    bitwise reference for ``kernels.fused_iter.build_fused_iteration``.
    The partials go through THE dot-block row reduction
    (``repro.core.types.dot_block_rows``) — a matmul would round
    differently at the ULP level."""
    from repro.core.types import dot_block_rows

    out, mat, u_new = fused_iter_unfused(S, idx, scal, apply_a, prec, layout)
    return out, dot_block_rows(mat, u_new)


def decode_attention_ref(q, k, v, kv_len):
    """q (B,Hkv,G,D), k/v (B,Hkv,S,D), kv_len scalar int -> (B,Hkv,G,D) f32.

    Normalized output (the oracle for o_unnorm / l)."""
    b, hkv, g, d = q.shape
    s = k.shape[2]
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum(
        "bhgd,bhsd->bhgs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    mask = jnp.arange(s)[None, None, None, :] < kv_len
    scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", w, v.astype(jnp.float32))
