"""Fused-iteration superkernel: the whole p(l)-CG vector phase in ONE
pass over the basis slab (DESIGN.md §13).

The per-iteration hot path of ``repro.core.pipelined_cg`` is, unfused,
~a dozen separate memory-bound passes over the (NV, N) state slab: the
SPMV (K1), the pointwise preconditioner, the pipeline-fill copies, the
2l+2 recurrence AXPYs of K4, the 2l+1 dot products of K5 and the x/p
updates of K6 — each re-reading basis vectors the previous op just
wrote.  This kernel is the deep-pipeline analogue of the kernel fusion
Cornelis/Cools/Vanroose assume for the local phase of p(l)-CG
(arXiv:1801.04728): per row tile, every basis vector is read from HBM
once, every updated row is written once, and the 2l+1 dot-block
*partials* are accumulated in VMEM — the single global reduction that
follows (``SolverOps.start_partials``) carries the same payload as the
unfused ``ops.start`` without touching the slab again.

Division of labour (see ``pipelined_cg.iteration``):

* the *scalar* phase (arrival scatter into G, K2 column correction, K3
  Hessenberg column) runs outside — O(l^2) scalars, no vector traffic;
* this kernel runs the *vector* phase from precomputed ring-row indices
  (``idx``, int32) and scalar coefficients (``scal``), so fused and
  unfused paths evaluate literally the same expressions on the same
  operands — the bitwise-parity contract of tests/test_fused_iter.py.

Tiling: the slab is blocked over its trailing N axis; the SPMV operand
(z ring-top, halo-extended on distributed substrates) rides as a
VMEM-resident side input prepared by the wrapper (one extra vector read
— the distributed halo exchange stays OUTSIDE the kernel, riding the
open reduction windows exactly as before, DESIGN.md §12).  Each grid
step emits its (2l+1,) dot partials into a per-tile output column; the
wrapper chain-sums the tiles (vmap-safe — no cross-grid-step carried
state).  The default is a single column tile: multi-tile runs change
only the dot partial summation ORDER (documented tight-tail behaviour,
same policy as DESIGN.md §12); all row updates stay bitwise regardless
of tiling.

The state slab is input/output-aliased (``input_output_aliases``), so on
TPU the iteration updates the slab in place — no per-iteration state
copy; ``donate_argnums`` at the jit boundaries of the slab programs
extends the same guarantee across chunks (DESIGN.md §13).  Off-TPU the
kernel runs in interpret mode, the repo-wide validation vehicle.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------- layout --

@dataclasses.dataclass(frozen=True)
class SlabLayout:
    """Row map of the contiguous p(l)-CG state slab (NV, N).

    Rows 0 .. (l+1)*RB-1 hold the l+1 auxiliary-basis ring buffers
    (basis k, ring slot j -> row k*RB + j), followed by the 3-deep u
    ring, the search direction p and the iterate x.  One array, one
    trailing N axis — exactly what a column-tiled kernel (and a
    ``donate_argnums``'d jit boundary) wants.

    ``recurrence`` selects the top-basis update of the vector phase
    (DESIGN.md §18): ``"ghysels"`` (default) recurs z^(l) through its own
    independent three-term recurrence (the paper's Alg. 1 line 22);
    ``"stable"`` recurs u first and recomputes z^(l)_{i+1} = M^{-1}
    u_{i+1} from it — the coupled recurrence of Cools/Cornelis/Vanroose
    (arXiv:1902.03100), which pins the auxiliary basis to the u ring so
    local rounding in the z recurrence can no longer drift independently.
    Exactly one pointwise preconditioner apply per iteration either way,
    and the early (pipeline-fill) phase is bitwise identical in both
    modes.  A trace-time choice: both kernel paths branch at build time,
    so the compiled HLO carries only the selected variant.
    """

    l: int
    RB: int
    recurrence: str = "ghysels"

    @property
    def u_off(self) -> int:
        return (self.l + 1) * self.RB

    @property
    def p_row(self) -> int:
        return self.u_off + 3

    @property
    def x_row(self) -> int:
        return self.u_off + 4

    @property
    def nv(self) -> int:
        return self.u_off + 5

    def zk_row(self, k: int, j):
        """Slab row of basis k's ring slot for iterate index j (traced)."""
        return k * self.RB + jnp.mod(j, self.RB)

    def u_row(self, j):
        return self.u_off + jnp.mod(j, 3)


# Index-vector layout (all entries are PRE-MODDED slab rows except the
# trailing flags).  Built by ``pipelined_cg.iteration``; consumed
# positionally by the kernel, so both sides share these offsets.
def idx_layout(l: int) -> dict[str, int]:
    return {
        "fill": 0,            # l entries : write rows zk(k, i+1)
        "rec_w": l,           # l entries : write rows zk(k, i-l+k+1)
        "rec_a": 2 * l,       # l entries : read  rows zk(k+1, i-l+k+1)
        "rec_b": 3 * l,       # l entries : read  rows zk(k, i-l+k)
        "rec_c": 4 * l,       # l entries : read  rows zk(k, i-l+k-1)
        "z_top": 5 * l,       # zk(l, i)
        "zl_im1": 5 * l + 1,  # zk(l, i-1)
        "z_w": 5 * l + 2,     # zk(l, i+1)   (write)
        "u_i": 5 * l + 3,     # u(i)
        "u_im1": 5 * l + 4,   # u(i-1)
        "u_w": 5 * l + 5,     # u(i+1)       (write)
        "p_im": 5 * l + 6,    # zk(0, i-l)
        "mat_v": 5 * l + 7,   # l entries : dot rows zk(0, i-2l+1+t), t<l
        "mat_z": 6 * l + 7,   # l-1 entries: dot rows zk(l, i-l+2+t), t<l-1
        "f_fill": 7 * l + 6,  # l flags    : pipeline-fill copy masks
        "f_late": 8 * l + 6,  # i >= l
        "f_first": 8 * l + 7,  # i == l
        "f_upd": 8 * l + 8,   # i >= l+1
        "size": 8 * l + 9,
    }


# Scalar-vector layout (solver dtype).
def scal_layout(l: int) -> dict[str, int]:
    return {
        "sig_i": 0,
        "gam_new": 1,
        "d2": 2,
        "dlt_safe": 3,
        "zet_prev": 4,
        "d_prev": 5,
        "eta_new_safe": 6,
        "eta0_safe": 7,
        "c1": 8,              # l entries : sig[k] - gam_new
        "size": 8 + l,
    }


# Telemetry-row layout (solver dtype; DESIGN.md §16).  One row of the
# (cap, K) on-device telemetry ring per iteration — every entry is a
# scalar the iteration ALREADY computed (replicated on distributed
# substrates), so recording it costs one K-wide row store and no
# communication.  Shared between the solver (which writes rows) and
# ``repro.core.types.TelemetrySlab`` / ``repro.obs`` (which decode them),
# the same positional-layout contract as ``idx_layout``/``scal_layout``.
def tel_layout(l: int) -> dict[str, int]:
    return {
        "iter": 0,         # global iteration counter (tot) of this row
        "upd": 1,          # solution updates after this iteration
        "rnorm": 2,        # recursive residual M-norm |zeta| (-1: none)
        "age": 3,          # in-flight reduction handles after this iter
        "breakdown": 4,    # square-root breakdown flag (line 11)
        "restart": 5,      # 1.0 on a restart boundary row
        "replacement": 6,  # 1.0 when the restart was a due residual
                           # replacement (not a breakdown)
        "gap": 7,          # governor's attainable-accuracy gap estimate
                           # (relative units; -1/0 when ungoverned,
                           # DESIGN.md §18)
        "action": 8,       # governor action on this row: 0 none,
                           # 1 gap-arm replacement, 2 patience-arm
                           # replacement, 3 stagnation declared
        "dots": 9,         # 2l+1 entries: the arrived dot block consumed
                           # this iteration (zeros during pipeline fill)
        "size": 9 + (2 * l + 1),
    }


# ------------------------------------------------------------ SPMV tiles --

@dataclasses.dataclass(frozen=True)
class FusedSpmv:
    """Operator plug-in for the superkernel.

    ``prepare(z_top)`` runs OUTSIDE the kernel (halo exchange, reshape)
    and returns the extra operand arrays; ``specs(block_n, n)`` their
    BlockSpecs; ``tile(extras, z_tile, pid, block_n)`` computes the
    az row tile inside the kernel — written to evaluate exactly the same
    jnp expression as the unfused ``ops.apply_a`` so row updates stay
    bitwise (tests/test_fused_iter.py).
    """

    prepare: Callable[[jax.Array], tuple]
    specs: Callable[[int, int], list]
    tile: Callable[[Sequence, jax.Array, jax.Array, int], jax.Array]


def resident_spmv(expr: Callable[[jax.Array], jax.Array],
                  prepare: Callable[[jax.Array], jax.Array],
                  ext_len: int) -> FusedSpmv:
    """Stencil-style SPMV: the (halo-extended) operand vector is VMEM-
    resident for the whole grid; each tile slices its rows out of the
    full stencil evaluation (a single-tile grid makes the slice the
    identity — the bitwise-default configuration)."""

    def specs(block_n: int, n: int):
        return [pl.BlockSpec((ext_len,), lambda i: (0,))]

    def tile(extras, z_tile, pid, block_n):
        az_full = expr(extras[0][...])
        return jax.lax.dynamic_slice(az_full, (pid * block_n,), (block_n,))

    return FusedSpmv(prepare=lambda z: (prepare(z),), specs=specs, tile=tile)


def diagonal_spmv(d: jax.Array) -> FusedSpmv:
    """A = diag(d): az is elementwise — the tile needs no halo at all."""

    def specs(block_n: int, n: int):
        return [pl.BlockSpec((block_n,), lambda i: (i,))]

    def tile(extras, z_tile, pid, block_n):
        return extras[0][...].astype(z_tile.dtype) * z_tile

    return FusedSpmv(prepare=lambda z: (d,), specs=specs, tile=tile)


def ell_spmv(cols: jax.Array, vals: jax.Array,
             prepare: Callable[[jax.Array], jax.Array],
             ext_len: int) -> FusedSpmv:
    """Unstructured padded-row ELL rows: cols/vals tile with the rows,
    the (halo-extended) x stays resident for the one gather per tile
    (same structure as ``kernels.ell_spmv``); the row sum uses the
    explicit add chain of ``linalg.sparse.ell_rowsum`` so local and
    distributed applies keep rounding identically (DESIGN.md §12)."""
    w = cols.shape[1]

    def specs(block_n: int, n: int):
        return [
            pl.BlockSpec((ext_len,), lambda i: (0,)),
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
        ]

    def tile(extras, z_tile, pid, block_n):
        x = extras[0][...]
        cols_t = extras[1][...]
        vals_t = extras[2][...].astype(z_tile.dtype)
        gathered = x[cols_t].astype(vals_t.dtype)
        acc = vals_t[..., 0] * gathered[..., 0]
        for s in range(1, w):
            acc = acc + vals_t[..., s] * gathered[..., s]
        return acc

    return FusedSpmv(prepare=lambda z: (prepare(z), cols, vals),
                     specs=specs, tile=tile)


# ---------------------------------------------------------------- kernel --

def build_fused_iteration(
    layout: SlabLayout,
    spmv: FusedSpmv,
    inv_diag: jax.Array | None = None,
    *,
    block_n: int | None = None,
    interpret: bool = False,
) -> Callable:
    """Compile-time assembly of the superkernel for one (operator,
    preconditioner, depth) configuration.

    Returns ``fiter(S, idx, scal) -> (S', partials)``: the full vector
    phase of one p(l)-CG iteration — SPMV + pointwise preconditioner +
    fill copies + K4 recurrences + ring writes + K6 x/p updates + local
    dot-block partials — with the slab read once and written once per
    tile (``input_output_aliases`` pins S' to S's buffer).

    ``inv_diag`` enables the pointwise (Jacobi) preconditioner tile;
    None means identity.  Block-structured preconditioners have no fused
    path (their block solve is not pointwise) — ``fused_iteration_factory``
    returns None for them and the solver falls back loudly.
    """
    l, nv = layout.l, layout.nv
    IX = idx_layout(l)
    IS = scal_layout(l)
    nd = 2 * l + 1
    has_prec = inv_diag is not None
    if layout.recurrence not in ("ghysels", "stable"):
        raise ValueError(f"unknown recurrence {layout.recurrence!r} "
                         "(want 'ghysels' or 'stable')")
    stable = layout.recurrence == "stable"

    def kernel(s_ref, idx_ref, scal_ref, *rest):
        *extra_refs, o_ref, acc_ref = rest
        if has_prec:
            *extra_refs, prec_ref = extra_refs
        s = s_ref[...]                       # (NV, BN) — the one slab read
        idx = idx_ref[...]
        scal = scal_ref[...]
        pid = pl.program_id(0)
        bn = s.shape[1]

        def get(row):
            return jax.lax.dynamic_index_in_dim(s, row, 0, keepdims=False)

        def put(out, row, vec):
            return jax.lax.dynamic_update_index_in_dim(out, vec, row, axis=0)

        late = idx[IX["f_late"]] != 0
        z_top = get(idx[IX["z_top"]])
        u_i = get(idx[IX["u_i"]])
        u_im1 = get(idx[IX["u_im1"]])

        # ---- (K1) SPMV + pointwise preconditioner ------------------------
        az = spmv.tile(extra_refs, z_top, pid, bn)
        u_new0 = az - scal[IS["sig_i"]] * u_i
        u_new = jnp.where(
            late,
            (u_new0 - scal[IS["gam_new"]] * u_i
             - scal[IS["d2"]] * u_im1) / scal[IS["dlt_safe"]],
            u_new0)
        if stable:
            # Coupled recurrence (arXiv:1902.03100, DESIGN.md §18): the
            # top basis vector is recomputed as M^{-1} u_{i+1} from the
            # freshly recurred u instead of recurring independently.
            # Early iterations are bitwise-unchanged: u_new == u_new0
            # there, so prec(u_new) == the ghysels path's z_new0.
            z_new = prec_ref[...] * u_new if has_prec else u_new
            z_fill = z_new
        else:
            z_new0 = prec_ref[...] * u_new0 if has_prec else u_new0
            zl_im1 = get(idx[IX["zl_im1"]])
            z_new = jnp.where(
                late,
                (z_new0 - scal[IS["gam_new"]] * z_top
                 - scal[IS["d2"]] * zl_im1) / scal[IS["dlt_safe"]],
                z_new0)
            z_fill = z_new0

        out = s
        # ---- pipeline-fill copies (lines 5-7) ----------------------------
        for k in range(l):
            row = idx[IX["fill"] + k]
            fill_k = idx[IX["f_fill"] + k] != 0
            out = put(out, row, jnp.where(fill_k, z_fill, get(row)))

        # ---- (K4) stable basis recurrences (masked late) -----------------
        recs = []
        for k in range(l):
            zk1 = get(idx[IX["rec_a"] + k])
            zm1 = get(idx[IX["rec_b"] + k])
            zm2 = get(idx[IX["rec_c"] + k])
            rec = (zk1 + scal[IS["c1"] + k] * zm1
                   - scal[IS["d2"]] * zm2) / scal[IS["dlt_safe"]]
            val = jnp.where(late, rec, get(idx[IX["rec_w"] + k]))
            recs.append(val)
            out = put(out, idx[IX["rec_w"] + k], val)

        out = put(out, idx[IX["z_w"]], z_new)
        out = put(out, idx[IX["u_w"]], u_new)

        # ---- (K5) local dot-block partials, accumulated in VMEM ----------
        # Rows i-2l+1..i+1 of G column i+1: the ZK^(0) V-range (last entry
        # freshly recurred), the ZK^(l) Z-range, and z_{i+1} itself.
        rows = [get(idx[IX["mat_v"] + t]) for t in range(l)] + [recs[0]]
        rows += [get(idx[IX["mat_z"] + t]) for t in range(l - 1)] + [z_new]
        mat = jnp.stack(rows)                # (2l+1, BN)

        # ---- (K6) solution/search-direction updates ----------------------
        x_old = s[layout.x_row]
        p_old = s[layout.p_row]
        p_first = s[0] / scal[IS["eta0_safe"]]
        p_new = (get(idx[IX["p_im"]])
                 - scal[IS["d_prev"]] * p_old) / scal[IS["eta_new_safe"]]
        x_new = x_old + scal[IS["zet_prev"]] * p_old
        do_upd = idx[IX["f_upd"]] != 0
        is_first = idx[IX["f_first"]] != 0
        out = out.at[layout.x_row].set(jnp.where(do_upd, x_new, x_old))
        out = out.at[layout.p_row].set(
            jnp.where(is_first, p_first,
                      jnp.where(do_upd, p_new, p_old)))

        o_ref[...] = out                     # the one slab write

        # Per-tile partials; the wrapper chain-sums tiles (a single tile
        # — the bitwise default — makes the sum the identity).  The
        # expression mirrors types.dot_block_rows exactly: a trailing-
        # axis reduce is bitwise-stable across the interpreter and vmap
        # where a dot_general is not.
        acc_ref[...] = (mat * u_new[None, :]).sum(axis=1)[:, None]

    def fiter(S: jax.Array, idx: jax.Array, scal: jax.Array):
        n = S.shape[1]
        bn = n if block_n is None else block_n
        assert n % bn == 0, (n, bn)
        nb = n // bn
        dtype = S.dtype
        z_top = jax.lax.dynamic_index_in_dim(S, idx[IX["z_top"]], 0,
                                             keepdims=False)
        extras = spmv.prepare(z_top)
        in_specs = [
            pl.BlockSpec((nv, bn), lambda i: (0, i)),       # S tiles
            pl.BlockSpec((IX["size"],), lambda i: (0,)),
            pl.BlockSpec((IS["size"],), lambda i: (0,)),
            *spmv.specs(bn, n),
        ]
        inputs = [S, idx, scal, *extras]
        if has_prec:
            in_specs.append(pl.BlockSpec((bn,), lambda i: (i,)))
            inputs.append(inv_diag.astype(dtype))
        out, acc = pl.pallas_call(
            kernel,
            grid=(nb,),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((nv, bn), lambda i: (0, i)),
                pl.BlockSpec((nd, 1), lambda i: (0, i)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((nv, n), dtype),
                jax.ShapeDtypeStruct((nd, nb), dtype),
            ],
            input_output_aliases={0: 0},     # slab updates in place
            interpret=interpret,
        )(*inputs)
        partials = acc[:, 0]
        for t in range(1, nb):               # static chain over tiles
            partials = partials + acc[:, t]
        return out, partials

    return fiter


def custom_call_hbm_bytes(layout: SlabLayout, n: int, dsize: int = 8,
                          extra_bytes: int = 0, n_tiles: int = 1) -> int:
    """HBM traffic XLA's cost analysis attributes to the compiled
    superkernel on TPU, where a ``pallas_call`` is an opaque custom call:
    operand bytes + result bytes — the slab once in, once out, the
    resident SPMV operand per tile, and the O(l) scalar/partial bundles.
    This is the ``fused_bytes_per_iter`` roofline of DESIGN.md §13; the
    interpret-mode numbers measured off-TPU upper-bound it (the
    interpreter re-materializes kernel-interior temporaries that the
    Mosaic compilation keeps in VMEM)."""
    slab = layout.nv * n * dsize
    idx_scal = (idx_layout(layout.l)["size"] * 4
                + scal_layout(layout.l)["size"] * dsize)
    partials = (2 * layout.l + 1) * dsize
    resident = n_tiles * (n * dsize + extra_bytes)
    return 2 * slab + resident + idx_scal + partials
