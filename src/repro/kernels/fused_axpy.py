"""Pallas kernel for the fused three-term recurrence — the paper's (K4).

Every basis update in p(l)-CG has the same shape (Alg. 1 lines 19-21):

    out = (zk1 + c1 * zm1 + c2 * zm2) * s

As three separate AXPYs this is 9 vector streams through HBM; fused it is 4
(3 reads + 1 write) — a 2.25x cut of the memory-roofline term of the
iteration body.  Scalars ride along as a tiny (4, 1) f32 operand replicated
to every grid step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_axpy_kernel(zk1_ref, zm1_ref, zm2_ref, c_ref, o_ref):
    c1 = c_ref[0, 0]
    c2 = c_ref[1, 0]
    s = c_ref[2, 0]
    x = zk1_ref[...].astype(jnp.float32)
    y = zm1_ref[...].astype(jnp.float32)
    z = zm2_ref[...].astype(jnp.float32)
    o_ref[...] = ((x + c1 * y + c2 * z) * s).astype(o_ref.dtype)


def fused_axpy3(
    zk1: jax.Array,
    zm1: jax.Array,
    zm2: jax.Array,
    c1: jax.Array,
    c2: jax.Array,
    scale: jax.Array,
    *,
    block_n: int = 65536,
    interpret: bool = False,
) -> jax.Array:
    """(zk1 + c1*zm1 + c2*zm2) * scale in a single HBM pass.

    1-D inputs of equal length N, N % block_n == 0 (ops.py pads)."""
    (n,) = zk1.shape
    assert zm1.shape == zm2.shape == (n,)
    assert n % block_n == 0, (n, block_n)
    nb = n // block_n
    coeffs = jnp.stack(
        [jnp.asarray(c1), jnp.asarray(c2), jnp.asarray(scale), jnp.zeros(())]
    ).astype(jnp.float32)[:, None]
    x2 = zk1.reshape(nb, block_n)
    return pl.pallas_call(
        _fused_axpy_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i: (i, 0)),
            pl.BlockSpec((4, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block_n), zk1.dtype),
        interpret=interpret,
    )(x2, zm1.reshape(nb, block_n), zm2.reshape(nb, block_n), coeffs).reshape(n)
