"""Inject dry-run / roofline JSON results into EXPERIMENTS.md tables.

    PYTHONPATH=src python scripts/fill_experiments.py
"""

import json
import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(path):
    p = os.path.join(ROOT, "results", path)
    if not os.path.exists(p):
        return []
    with open(p) as f:
        return json.load(f)


def human(x):
    if x is None:
        return "-"
    for unit, f in (("T", 1e12), ("G", 1e9), ("M", 1e6), ("K", 1e3)):
        if abs(x) >= f:
            return f"{x/f:.2f}{unit}"
    return f"{x:.3g}"


def dryrun_table(recs):
    hdr = ("| arch | shape | mesh | compile (s) | per-dev FLOPs | per-dev "
           "HBM B | coll B | dominant | arg B/dev |\n"
           "|---|---|---|---|---|---|---|---|---|")
    rows = []
    for r in recs:
        arg = (r.get("memory") or {}).get("argument_bytes")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} "
            f"| {human(r['flops'])} | {human(r['hbm_bytes'])} "
            f"| {human(r['coll_bytes'])} | {r['dominant']} | {human(arg)} |")
    return hdr + "\n" + "\n".join(rows)


def roofline_table(recs):
    hdr = ("| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
           "| dominant | useful | MFU-bound | what would move the dominant "
           "term |\n|---|---|---|---|---|---|---|---|---|")
    rows = []
    for r in recs:
        note = dominant_note(r)
        uf = r.get("useful_fraction")
        mfu = r.get("mfu")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.2e} "
            f"| {r['t_memory']:.2e} | {r['t_collective']:.2e} "
            f"| {r['dominant']} | {uf:.3f} | {mfu:.3f} | {note} |")
    return hdr + "\n" + "\n".join(rows)


def dominant_note(r):
    d = r["dominant"]
    if d == "collective":
        kinds = r.get("coll_per_kind", {})
        big = max(kinds, key=lambda k: kinds[k]["bytes"]) if kinds else "?"
        return (f"cut {big} volume: larger per-chip work (less TP) or "
                f"overlap with compute (pipelined reduction)")
    if d == "memory":
        return "fuse elementwise chains / fewer remat passes / bf16 master IO"
    return "compute-bound: already near the useful-flops ceiling"


def replace_block(text, marker, table):
    pat = re.compile(rf"<!-- {marker}.*?-->", re.S)
    return pat.sub(f"<!-- {marker} -->\n\n{table}\n", text, count=1)


def main():
    exp_path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(exp_path).read()
    dr = load("dryrun_all.json")
    if dr:
        text = replace_block(text, "DRYRUN-TABLE", dryrun_table(dr))
        print(f"dry-run table: {len(dr)} rows")
    rf = load("roofline_baseline.json")
    if rf:
        text = replace_block(text, "ROOFLINE-TABLE", roofline_table(rf))
        print(f"roofline table: {len(rf)} rows")
    open(exp_path, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
