#!/usr/bin/env python
"""Docs-citation checker: every ``DESIGN.md §N`` reference in the code
must point at a section that actually exists in DESIGN.md, and every
``arXiv:NNNN.NNNNN`` paper citation must resolve to a reference listed
in DESIGN.md (its References section) or PAPERS.md.

The repo's docstrings cite design sections (e.g. ``DESIGN.md §2``,
``DESIGN.md §2/§8``); this grew stale once — the document didn't exist —
so the check is wired into the test suite (tests/test_docs.py).  Paper
ids joined the check with DESIGN.md §12: a citation nobody can look up
is as dangling as a missing section.  Exit status 0 when every citation
resolves, 1 otherwise (with a per-citation report).

Usage:
    python scripts/check_docs.py [--root PATH]
"""

from __future__ import annotations

import argparse
import os
import re
import sys

# "DESIGN.md §2" and multi-refs "DESIGN.md §2/§8" (slash-separated).
CITE_RE = re.compile(r"DESIGN\.md[ \t]*(§\d+(?:[ \t]*/[ \t]*§\d+)*)")
SEC_NUM_RE = re.compile(r"§(\d+)")
# DESIGN.md section headers: "## §N — title"
HEADER_RE = re.compile(r"^##\s*§(\d+)\b", re.MULTILINE)
# Paper citations: "arXiv:1905.06850" in code/docstrings; reference
# lists may also carry the id inside an arxiv.org URL.
ARXIV_RE = re.compile(r"arXiv:(\d{4}\.\d{4,5})")
ARXIV_ANY_RE = re.compile(r"(?:arXiv:|arxiv\.org/(?:abs|pdf)/)"
                          r"(\d{4}\.\d{4,5})", re.IGNORECASE)

# Where citations live: python sources and markdown docs.
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "scripts")
SCAN_EXTS = (".py",)


def design_sections(root: str) -> set[int] | None:
    """Section numbers declared in DESIGN.md, or None if it's missing."""
    path = os.path.join(root, "DESIGN.md")
    if not os.path.isfile(path):
        return None
    with open(path, encoding="utf-8") as f:
        return {int(m) for m in HEADER_RE.findall(f.read())}


def find_citations(root: str) -> list[tuple[str, int, int]]:
    """(relative path, line number, cited section) for every citation."""
    out = []
    for d in SCAN_DIRS:
        base = os.path.join(root, d)
        if not os.path.isdir(base):
            continue
        for dirpath, _dirnames, filenames in os.walk(base):
            for fname in sorted(filenames):
                if not fname.endswith(SCAN_EXTS):
                    continue
                path = os.path.join(dirpath, fname)
                rel = os.path.relpath(path, root)
                with open(path, encoding="utf-8", errors="replace") as f:
                    for lineno, line in enumerate(f, 1):
                        for m in CITE_RE.finditer(line):
                            for num in SEC_NUM_RE.findall(m.group(1)):
                                out.append((rel, lineno, int(num)))
    return out


def known_arxiv_ids(root: str) -> set[str]:
    """arXiv ids listed in DESIGN.md or PAPERS.md (by id or URL)."""
    ids: set[str] = set()
    for doc in ("DESIGN.md", "PAPERS.md"):
        path = os.path.join(root, doc)
        if not os.path.isfile(path):
            continue
        with open(path, encoding="utf-8") as f:
            ids.update(ARXIV_ANY_RE.findall(f.read()))
    return ids


def find_arxiv_citations(root: str) -> list[tuple[str, int, str]]:
    """(relative path, line number, arxiv id) for every code citation."""
    out = []
    for d in SCAN_DIRS:
        base = os.path.join(root, d)
        if not os.path.isdir(base):
            continue
        for dirpath, _dirnames, filenames in os.walk(base):
            for fname in sorted(filenames):
                if not fname.endswith(SCAN_EXTS):
                    continue
                path = os.path.join(dirpath, fname)
                rel = os.path.relpath(path, root)
                with open(path, encoding="utf-8", errors="replace") as f:
                    for lineno, line in enumerate(f, 1):
                        for aid in ARXIV_RE.findall(line):
                            out.append((rel, lineno, aid))
    return out


def check(root: str = ".", verbose: bool = True) -> int:
    """Return the number of problems (0 == docs are consistent)."""
    sections = design_sections(root)
    cites = find_citations(root)
    problems = 0
    if sections is None:
        if verbose:
            print(f"check_docs: {root}/DESIGN.md is MISSING "
                  f"({len(cites)} citation(s) dangling)")
        return max(len(cites), 1)
    for rel, lineno, num in cites:
        if num not in sections:
            problems += 1
            if verbose:
                print(f"check_docs: {rel}:{lineno} cites DESIGN.md §{num} "
                      f"— no such section (have: "
                      f"{', '.join(f'§{s}' for s in sorted(sections))})")
    known = known_arxiv_ids(root)
    acites = find_arxiv_citations(root)
    for rel, lineno, aid in acites:
        if aid not in known:
            problems += 1
            if verbose:
                print(f"check_docs: {rel}:{lineno} cites arXiv:{aid} — not "
                      f"listed in DESIGN.md References or PAPERS.md")
    if verbose and problems == 0:
        print(f"check_docs: OK — {len(cites)} section citation(s) + "
              f"{len(acites)} paper citation(s) across the tree, "
              f"{len(sections)} section(s) in DESIGN.md, "
              f"{len(known)} known reference(s)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    args = ap.parse_args(argv)
    return 1 if check(args.root) else 0


if __name__ == "__main__":
    sys.exit(main())
