#!/usr/bin/env python
"""Multi-controller parity check + strong-scaling study for the
``multiprocess`` reduction backend, exercised across REAL process
boundaries (DESIGN.md §3/§14/§17).

Default mode (CI ``multiprocess`` job, tests/test_multiprocess.py): pick
a free coordinator port (retrying bind collisions via
``repro.parallel.fabric``) and spawn ``--num-processes`` copies of this
script (default 2), each a real ``jax.distributed`` controller with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — a 2-process x
4-device job whose solver mesh spans all 8 devices, so the fused
dot-block psum, the halo ppermutes AND the staged ladder's tagged hop
permutes genuinely cross the process boundary (the paper's MPI world).

Each process runs the same program (multi-controller SPMD): classic CG
and p(l)-CG on a structured stencil AND an unstructured FEM SparseOp,
asserting residual-history parity against the single-device ``local``
backend.  The run then exercises the STAGED HOP LADDER across the real
process boundary (DESIGN.md §17): ``reduction="staged"`` must run the
ladder for real — mode ``staged``, no fallback, the
``backend_reduction_fallback`` gauge pinned 0 — with residual histories
BITWISE against the local ``virtual_shards`` ladder oracle and ZERO
dot-block all-reduces in the compiled staged solve.

Chaos mode (``--chaos``, DESIGN.md §18): spawn the same real process
group, inject a seeded reduction-payload fault (``repro.chaos``) into
every rank's staged dot-block wait, and run a GOVERNED stable p(l)-CG
solve.  Every rank must emit a byte-identical ``CHAOS-GOV`` row —
replacement count, iteration count and bitwise residual-history hash —
proving the stability governor fires identically on every process
(divergent governor control flow would deadlock or diverge the very
next collective).

Scaling-study mode (``--study``, CI ``scaling-study`` job): a strong-
scaling sweep at FIXED n over 1..N processes (default 1,2,4 ranks x 1
device — the paper's Cori curve shape, reproduced on our own fabric):
per-P measured seconds/iteration staged vs monolithic (two-budget
differencing, min over repeats), bitwise parity vs the ladder oracle,
compiled-HLO structure (all-reduce count, hops/window), and per-process
hop/halo staggering timelines via the DESIGN.md §16 exporter
(``TIMELINE_scaling_proc*.json`` at the widest P).  Emits
``BENCH_scaling.json``; CI gates it via scripts/check_bench.py —
bitwise-parity floor, zero-all-reduce ceiling, hops floor, and
staged <= monolithic wall clock at P=2 (on a 1-core container every
collective costs a scheduler slice, so the P-1-hop ladder cannot
wall-clock-win at P>=3 — those rows gate at the documented
hop-serialization ceiling instead; see DESIGN.md §17).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.parallel.fabric import (FabricError, launch_fabric,  # noqa: E402
                                   run_resilient)

STUDY_MARKER = "SCALING-JSON "
CHAOS_MARKER = "CHAOS-GOV "
RECOVERY_MARKER = "RECOVERY-JSON "
RECOVERY_KILL = "RECOVERY-KILL "
RECOVERY_RESUMED = "RECOVERY-RESUMED "


def _child_jax_setup():
    import jax

    # Cross-process CPU collectives need the gloo TCP backend (the
    # backend constructor also selects it; doing it here too keeps the
    # setup explicit for jax versions that read the env var instead).
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except AttributeError:  # pragma: no cover - very old/new jax
        pass
    jax.config.update("jax_enable_x64", True)
    return jax


def _time_per_iter(be, op, b, sig, l, iters=(20, 60), repeats=5):
    """Measured seconds/iteration on a live backend: two fixed budgets
    (tol=0 disables early exit), differenced to cancel init/launch
    overhead, min over ``repeats`` (launch.autotune.measured_runner's
    policy)."""
    import time

    import jax

    def run(maxit):
        solver = be.make_solver(op, "plcg", None, l=l, sigmas=sig,
                                tol=0.0, maxit=maxit)
        jax.block_until_ready(solver(b).x)          # compile + warmup
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(solver(b).x)
            best = min(best, time.perf_counter() - t0)
        return best

    lo, hi = iters
    t_lo, t_hi = run(lo), run(hi)
    if t_hi <= t_lo:
        return t_hi / hi
    return (t_hi - t_lo) / (hi - lo)


def child(coordinator: str, num_processes: int, process_id: int) -> int:
    jax = _child_jax_setup()
    import warnings

    import jax.numpy as jnp
    import numpy as np

    from repro.core.chebyshev import shifts_for_operator
    from repro.linalg import Stencil2D5, random_fem_mesh, rcm_reorder
    from repro.obs.metrics import default_registry
    from repro.parallel import get_backend
    from repro.parallel.reduction import ReductionFallbackWarning
    from repro.utils.trace import plcg_overlap_report

    be = get_backend(
        "multiprocess",
        coordinator_address=coordinator,
        num_processes=num_processes,
        process_id=process_id,
    )
    n_dev = be.n_shards
    assert jax.process_count() == num_processes, jax.process_count()
    assert n_dev == num_processes * jax.local_device_count(), n_dev
    print(f"[p{process_id}] {be.describe()}", flush=True)
    local = get_backend("local")

    problems = [
        ("stencil2d", Stencil2D5(32, 24)),
        ("fem-sparse", rcm_reorder(random_fem_mesh(0, 400))[0]),
    ]
    for name, op in problems:
        b = jnp.asarray(np.random.default_rng(7).standard_normal(op.n))
        sig = shifts_for_operator(op, 2)
        for method, kw in (("cg", {}), ("plcg", dict(l=2, sigmas=sig))):
            kw = dict(kw, tol=1e-8, maxit=800)
            res_m = be.solve(op, b, method=method, **kw)
            res_l = local.solve(op, b, method=method, **kw)
            hm = np.asarray(res_m.res_history)
            hl = np.asarray(res_l.res_history)
            n0 = float(res_l.norm0)
            m = (hm >= 0) & (hl >= 0)
            assert m.sum() > 5, (name, method, int(m.sum()))
            # Histories are norm0-normalized for comparison: Krylov
            # recurrences amplify reduction-order ULPs chaotically as the
            # residual shrinks (tests/test_distributed.py measures a 0.5
            # relative drift from a single ULP on b), so the contract is
            # a TIGHT head (pre-amplification — a wrong operator or halo
            # breaks here immediately) and a bounded tail.
            diff = np.abs(hm[m] - hl[m]) / n0
            assert diff[:10].max() < 1e-8, (name, method, diff[:10].max())
            assert diff.max() < 5e-2, (name, method, diff.max())
            d_it = abs(int(res_m.iters) - int(res_l.iters))
            assert d_it <= 5, (name, method, int(res_m.iters),
                               int(res_l.iters))
            assert bool(res_m.converged)
            print(f"[p{process_id}] {name}/{method}: iters "
                  f"{int(res_m.iters)} vs local {int(res_l.iters)}, "
                  f"max|dh|/norm0 {diff.max():.2e}", flush=True)

    # ---- staged hop ladder across the real process boundary (§17) -------
    # The ladder must RUN — no capability downgrade, no warning, gauge
    # pinned 0 — with tagged per-hop permutes as the only dot-block wire
    # traffic and histories bitwise vs the single-device virtual-shards
    # ladder oracle (same ring size, same rank-ordered combine).
    op = Stencil2D5(32, 24)
    b = jnp.asarray(np.random.default_rng(7).standard_normal(op.n))
    sig = shifts_for_operator(op, 2)
    stages = 2
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        be_staged = get_backend(
            "multiprocess",
            coordinator_address=coordinator,
            num_processes=num_processes,
            process_id=process_id,
            reduction="staged",
            reduction_stages=stages,
        )
    assert type(be_staged).supports_staged_reduction
    assert be_staged.reduction_mode == "staged", be_staged.reduction_mode
    assert be_staged.reduction_fallback is None
    assert be_staged.reduction_cfg is not None
    assert not any(isinstance(w.message, ReductionFallbackWarning)
                   for w in caught), [str(w.message) for w in caught]
    g = default_registry().get("backend_reduction_fallback")
    assert g is not None
    assert g.value(labels={"backend": "multiprocess"}) == 0.0
    assert be_staged.cross_process_edges() == num_processes
    assert be_staged.hop_wire() == "gloo", be_staged.hop_wire()

    kw = dict(method="plcg", l=2, sigmas=sig, tol=1e-8, maxit=800)
    res_s = be_staged.solve(op, b, **kw)
    oracle = get_backend("local", reduction="staged",
                         virtual_shards=n_dev, reduction_stages=stages)
    res_o = oracle.solve(op, b, **kw)
    hs, ho = np.asarray(res_s.res_history), np.asarray(res_o.res_history)
    assert np.array_equal(hs, ho), np.abs(hs - ho).max()
    assert bool(res_s.converged)

    # fp32 wire payload: both sides round at the start site and Kahan-
    # accumulate at the wait, so cross-process stays bitwise vs the
    # fp32-wire oracle too.
    be_32 = get_backend(
        "multiprocess", coordinator_address=coordinator,
        num_processes=num_processes, process_id=process_id,
        reduction="staged", reduction_stages=stages,
        reduction_dtype=jnp.float32)
    or_32 = get_backend("local", reduction="staged", virtual_shards=n_dev,
                        reduction_stages=stages,
                        reduction_dtype=jnp.float32)
    h32s = np.asarray(be_32.solve(op, b, **kw).res_history)
    h32o = np.asarray(or_32.solve(op, b, **kw).res_history)
    assert np.array_equal(h32s, h32o), np.abs(h32s - h32o).max()

    # Compiled staged solve: ZERO dot-block all-reduces on the wire —
    # only tagged hop permutes, one logical start per window.
    bspec = jax.ShapeDtypeStruct((op.n,), jnp.float64)
    rep = plcg_overlap_report(be_staged, op, bspec, l=2, window=4,
                              sigmas=sig)
    assert rep.n_collectives == 0, rep.n_collectives
    assert min(rep.reduce_hops_per_window.values()) >= 1, \
        rep.reduce_hops_per_window
    assert max(rep.staged_starts_per_window.values()) == 1, \
        rep.staged_starts_per_window
    print(f"[p{process_id}] staged ladder CROSS-PROCESS: bitwise vs "
          f"virtual-shards oracle (fp64 + fp32 wire), 0 dot-block "
          f"all-reduces, hops/window "
          f"{dict(rep.reduce_hops_per_window)}, "
          f"{be_staged.cross_process_edges()} cross-process edge(s)/hop "
          f"over {be_staged.hop_wire()}", flush=True)

    # ---- instrumented cross-process solve + timeline export (§16) -------
    # Every process runs the SAME instrumented solve (telemetry values
    # are post-psum replicated scalars — no new collectives cross the
    # wire) and exports its own Chrome-trace JSON; the launcher/CI pick
    # the files up as artifacts.
    from repro.obs import Timeline, telemetry_track

    tl = Timeline()
    tl.name_thread(1, 1, "cross-process solve phases")
    with tl.span("plcg[instrumented, cross-process]"):
        res_t = be.solve(op, b, method="plcg", l=2, sigmas=sig, tol=1e-8,
                         maxit=800, telemetry_cap=128)
        jax.block_until_ready(res_t.res_history)
    assert res_t.telemetry is not None
    tel = np.asarray(res_t.telemetry)
    assert (tel[:, 0] >= 0).any(), "telemetry ring never written"
    tl.merge(telemetry_track(res_t.telemetry, l=2))
    tl.meta["parity"] = {
        "process_id": process_id, "num_processes": num_processes,
        "backend": be.name, "reduction_mode": be.reduction_mode,
        "staged_wire": be_staged.hop_wire(),
    }
    path = tl.save(f"TIMELINE_parity_proc{process_id}.json")
    print(f"[p{process_id}] timeline -> {path}", flush=True)

    print(f"[p{process_id}] MULTIPROC-PARITY-OK", flush=True)
    return 0


def study_child(coordinator: str, num_processes: int, process_id: int,
                args) -> int:
    """One rank of one strong-scaling point: measure staged vs monolithic
    seconds/iteration at fixed n, assert ladder parity, extract the
    compiled hop/halo schedule, optionally export this rank's timeline."""
    jax = _child_jax_setup()
    import jax.numpy as jnp
    import numpy as np

    from repro.core.chebyshev import shifts_for_operator
    from repro.linalg import Stencil2D5
    from repro.parallel import get_backend
    from repro.utils.trace import plcg_overlap_report

    kw_be = dict(coordinator_address=coordinator,
                 num_processes=num_processes, process_id=process_id)
    be_mono = get_backend("multiprocess", **kw_be)
    n_dev = be_mono.n_shards
    stages = max(1, min(args.stages, max(n_dev - 1, 1)))
    be_staged = get_backend("multiprocess", **kw_be, reduction="staged",
                            reduction_stages=stages)
    assert be_staged.reduction_mode == "staged"

    op = Stencil2D5(args.nx, args.ny)
    l = args.l
    sig = shifts_for_operator(op, l)
    b = jnp.asarray(np.random.default_rng(7).standard_normal(op.n))
    budgets = (args.budget_lo, args.budget_hi)

    t_mono = _time_per_iter(be_mono, op, b, sig, l, iters=budgets,
                            repeats=args.repeats)
    t_staged = _time_per_iter(be_staged, op, b, sig, l, iters=budgets,
                              repeats=args.repeats)

    # Bitwise ladder parity vs the single-device virtual-shards oracle.
    kw = dict(method="plcg", l=l, sigmas=sig, tol=1e-8, maxit=1200)
    res_s = be_staged.solve(op, b, **kw)
    oracle = get_backend("local", reduction="staged",
                         virtual_shards=n_dev, reduction_stages=stages)
    res_o = oracle.solve(op, b, **kw)
    hs, ho = np.asarray(res_s.res_history), np.asarray(res_o.res_history)
    parity_bitwise = bool(np.array_equal(hs, ho))

    # Compiled staged schedule: the structural columns of the study.
    bspec = jax.ShapeDtypeStruct((op.n,), jnp.float64)
    rep = plcg_overlap_report(be_staged, op, bspec, l=l, window=l + 2,
                              sigmas=sig)

    if args.emit_timelines:
        # Per-rank hop/halo staggering timeline via the §16 exporter:
        # measured host spans + the compiled schedule track (reduction
        # windows vs ladder hops vs halo permutes) + the telemetry ring.
        from repro.obs.timeline import solve_timeline

        tl, _res = solve_timeline(be_staged, op, b, l=l, sigmas=sig,
                                  telemetry_cap=128, tol=1e-8, maxit=1200)
        tl.meta["scaling_study"] = {
            "process_id": process_id, "num_processes": num_processes,
            "n": int(op.n), "stages": stages,
            "wire": be_staged.hop_wire(),
            "cross_process_edges": be_staged.cross_process_edges(),
        }
        path = tl.save(f"TIMELINE_scaling_proc{process_id}.json")
        print(f"[p{process_id}] timeline -> {path}", flush=True)

    row = {
        "procs": num_processes,
        "devices": n_dev,
        "stages": stages,
        "staged_iter_time_s": t_staged,
        "monolithic_iter_time_s": t_mono,
        "staged_over_monolithic": t_staged / t_mono,
        "parity_bitwise": parity_bitwise,
        "staged_allreduces": rep.n_collectives,
        # P=1 has a hopless ladder (0-hop ring): empty window dicts.
        "hops_per_window_min": min(rep.reduce_hops_per_window.values(),
                                   default=0),
        "staged_starts_per_window_max":
            max(rep.staged_starts_per_window.values(), default=0),
        "iters_staged": int(res_s.iters),
        "iters_oracle": int(res_o.iters),
        "wire": be_staged.hop_wire(),
        "cross_process_edges": be_staged.cross_process_edges(),
    }
    if process_id == 0:
        print(STUDY_MARKER + json.dumps(row), flush=True)
    print(f"[p{process_id}] P={num_processes} staged "
          f"{t_staged * 1e6:.0f}us/iter vs mono {t_mono * 1e6:.0f}us/iter "
          f"(x{t_staged / t_mono:.2f}), parity_bitwise={parity_bitwise}, "
          f"allreduces={rep.n_collectives}", flush=True)
    print(f"[p{process_id}] SCALING-OK", flush=True)
    return 0


def chaos_child(coordinator: str, num_processes: int,
                process_id: int) -> int:
    """One rank of the cross-process chaos drill (DESIGN.md §18): run a
    GOVERNED stable p(l)-CG solve over the real staged ladder with a
    seeded reduction-payload fault injected at the dot-block wait, and
    emit a ``CHAOS-GOV`` marker the launcher byte-compares across ranks.

    The injected noise is a value-hash of the post-combine (replicated)
    payload, so every rank perturbs identically and the governor's
    replacement decisions — control flow driven by the perturbed dots —
    stay lockstep SPMD: same replacement count, same residual history,
    bit for bit.  A rank whose governor fired differently would diverge
    at the next collective; the identical markers prove it did not.
    """
    jax = _child_jax_setup()
    import hashlib

    import jax.numpy as jnp
    import numpy as np

    from repro.chaos import ChaosConfig, chaos_ops
    from repro.core import pipelined_cg
    from repro.core.chebyshev import shifts_for_operator
    from repro.linalg import Stencil2D5
    from repro.parallel import get_backend
    from repro.parallel.fabric import touch_heartbeat
    from repro.stability import GovernorConfig
    from repro.stability import model as gov_model

    touch_heartbeat()
    be = get_backend(
        "multiprocess", coordinator_address=coordinator,
        num_processes=num_processes, process_id=process_id,
        reduction="staged", reduction_stages=2)
    assert be.reduction_mode == "staged", be.reduction_mode
    print(f"[p{process_id}] {be.describe()}", flush=True)

    op = Stencil2D5(32, 24)
    b = jnp.asarray(np.random.default_rng(7).standard_normal(op.n))
    sig = shifts_for_operator(op, 2)
    chaos = ChaosConfig(seed=7, payload_rel_amp=1e-5)
    kw = dict(l=2, sigmas=sig, tol=1e-5, maxit=400,
              recurrence="stable", governor=GovernorConfig())

    # Only replicated pieces come back through the shard_map (out_specs
    # P()): the residual history, governor vector and scalars are all
    # post-psum values, identical on every device.
    def fn(ops, bb):
        res = pipelined_cg.solve(chaos_ops(ops, chaos), bb, **kw)
        return res.res_history, res.governor, res.iters, res.converged

    hist, gov, iters, conv = be.run(fn, op, b)
    touch_heartbeat()
    hist, gov = np.asarray(hist), np.asarray(gov)
    repl = int(gov[gov_model.REPL])
    assert bool(conv), "governed chaos solve failed to converge"
    assert repl >= 1, "governor never fired under injected perturbation"
    row = {
        "converged": bool(conv),
        "iters": int(iters),
        "replacements": repl,
        "stagnated": int(gov[gov_model.STAGNATED]),
        "governor_sha": hashlib.sha256(gov.tobytes()).hexdigest(),
        "history_sha": hashlib.sha256(hist.tobytes()).hexdigest(),
    }
    print(CHAOS_MARKER + json.dumps(row, sort_keys=True), flush=True)
    print(f"[p{process_id}] governed chaos solve: iters {row['iters']}, "
          f"{repl} governed replacement(s), history sha "
          f"{row['history_sha'][:16]}", flush=True)
    print(f"[p{process_id}] CHAOS-OK", flush=True)
    return 0


def recovery_child(coordinator: str, num_processes: int, process_id: int,
                   args) -> int:
    """One rank of the kill-a-rank recovery drill (DESIGN.md §19).

    Every rank runs the same checkpointed staged p(l)-CG solve over the
    real process fabric, touching its heartbeat and ticking the
    environment-scripted iteration faults at every drained-ring
    boundary.  On attempt 1 the fault plan kills one rank mid-solve; on
    attempt 2 (clean environment, ``resume=True`` on the shared
    checkpoint directory) the group restores the last snapshot, resumes
    and converges — rank 0 then replays the UNINTERRUPTED local
    virtual-shards oracle of the same segmented config and asserts the
    resumed cross-process history is bitwise identical to it (head from
    the checkpoint, tail recomputed — one history, no seam).
    """
    jax = _child_jax_setup()           # noqa: F841 - configures x64/gloo
    import jax.numpy as jnp
    import numpy as np

    from repro.chaos import install_iteration_faults
    from repro.checkpoint import LAST_RESTORE, CheckpointConfig
    from repro.core.chebyshev import shifts_for_operator
    from repro.linalg import Stencil2D5
    from repro.parallel import get_backend
    from repro.parallel.fabric import install_sigterm_handler, touch_heartbeat

    # A peer death leaves this rank blocked in a collective; the
    # launcher's SIGTERM must turn that into a prompt, distinct-status
    # exit instead of a watchdog-escalated SIGKILL.
    install_sigterm_handler()
    touch_heartbeat()
    faults = install_iteration_faults(process_id)

    be = get_backend(
        "multiprocess", coordinator_address=coordinator,
        num_processes=num_processes, process_id=process_id,
        reduction="staged", reduction_stages=args.stages)
    assert be.reduction_mode == "staged", be.reduction_mode
    n_dev = be.n_shards
    print(f"[p{process_id}] attempt {args.attempt}: {be.describe()}, "
          f"faults armed={faults.armed}", flush=True)

    op = Stencil2D5(32, 24)            # the parity/chaos drill problem
    b = jnp.asarray(np.random.default_rng(7).standard_normal(op.n))
    sig = shifts_for_operator(op, args.l)
    # Staged + UNFUSED is the bitwise-elastic configuration: fused
    # iterations compile different (per-substrate) contraction orders,
    # so their cross-substrate parity is certified, not bitwise
    # (DESIGN.md §19 honesty notes).
    kw = dict(l=args.l, sigmas=sig, tol=1e-10, maxit=400,
              fused_iteration=False)

    def on_boundary(upd: int) -> None:
        # ``upd`` = global solution updates (boundaries land at exact
        # multiples of ``every`` updates; plcg's post-restart ring
        # refill advances tot but not upd).
        touch_heartbeat()
        if faults.kill_at_iter is not None and upd >= faults.kill_at_iter:
            # Last words before the scripted death: which boundary this
            # rank died at, for the launcher's recomputed-iters metric.
            print(RECOVERY_KILL + json.dumps(
                {"rank": process_id, "upd": int(upd), "t": time.time()}),
                flush=True)
        faults.tick(upd)

    cfg = CheckpointConfig(every=args.every, directory=args.ckpt_dir,
                           keep=3, resume=True, on_boundary=on_boundary)
    res = be.solve(op, b, method="plcg", checkpoint=cfg, **kw)
    hist = np.asarray(res.res_history)
    resumed_tot = resumed_upd = 0
    if LAST_RESTORE:
        resumed_tot = int(LAST_RESTORE[-1].meta["tot"])
        resumed_upd = int(LAST_RESTORE[-1].meta["upd"])
        print(RECOVERY_RESUMED + json.dumps(
            {"rank": process_id, "tot": resumed_tot, "upd": resumed_upd,
             "t": time.time(),
             "path": os.path.basename(LAST_RESTORE[-1].path)}), flush=True)
    assert bool(res.converged), "recovery solve failed to converge"

    if process_id == 0:
        # Uninterrupted oracle: the SAME segmented config (same
        # effective replacement cadence) on the local virtual-shards
        # ladder, never killed, never restored.  directory=None keeps
        # the segmented drive without persisting.
        oracle = get_backend("local", reduction="staged",
                             virtual_shards=n_dev,
                             reduction_stages=args.stages)
        res_o = oracle.solve(op, b, method="plcg",
                             checkpoint=CheckpointConfig(every=args.every),
                             **kw)
        ho = np.asarray(res_o.res_history)
        parity = bool(hist.shape == ho.shape and np.array_equal(hist, ho))
        row = {
            "attempt": args.attempt,
            "procs": num_processes,
            "devices": n_dev,
            "resumed_tot": resumed_tot,
            "resumed_upd": resumed_upd,
            "iters": int(res.iters),
            "iters_oracle": int(res_o.iters),
            "converged": bool(res.converged),
            "parity_bitwise": parity,
        }
        print(RECOVERY_MARKER + json.dumps(row), flush=True)
        assert parity, (
            "resumed cross-process history diverged from the "
            f"uninterrupted local oracle (max |dh| = "
            f"{np.abs(hist - ho).max() if hist.shape == ho.shape else 'shape'})")
    print(f"[p{process_id}] RECOVERY-OK", flush=True)
    return 0


def recovery(args) -> int:
    """Kill-a-rank recovery drill launcher (DESIGN.md §19).

    Attempt 1 ships a seeded iteration-indexed kill plan for one rank
    (``repro.chaos``); the launcher's watchdog converts the death into
    a typed :class:`FabricProcessError`, ``run_resilient`` tears the
    group down and respawns a clean fabric on a fresh coordinator port;
    attempt 2 resumes from the shared checkpoint directory and must
    converge with a residual history BITWISE equal to the uninterrupted
    local virtual-shards oracle.  Emits a ``RECOVERY-RESULT`` JSON line
    (detection/respawn seconds, recomputed iterations, parity bit) that
    benchmarks/recovery_bench.py turns into the gated
    ``BENCH_recovery.json``.
    """
    from repro.chaos import ChaosConfig

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro-recovery-")
    plan_env = ChaosConfig(
        seed=7, kill_rank=args.kill_rank,
        kill_rank_at_iter=args.kill_at).fault_plan().env()
    t_attempt: dict[int, float] = {}

    def attempt_env(attempt: int) -> dict:
        # Called right before each fabric launch: timestamping here is
        # what separates detection (death -> teardown done) from
        # respawn (relaunch -> solve resumed).  The kill plan is armed
        # on the FIRST attempt only; the respawn runs clean.
        t_attempt[attempt] = time.time()
        return dict(plan_env) if attempt == 1 else {}

    def argv(coordinator: str, k: int, p: int, a: int) -> list[str]:
        return [sys.executable, os.path.abspath(__file__),
                "--coordinator", coordinator,
                "--num-processes", str(p),
                "--process-id", str(k),
                "--recovery-child",
                "--ckpt-dir", ckpt_dir,
                "--every", str(args.every),
                "--l", str(args.l), "--stages", str(args.stages),
                "--attempt", str(a)]

    try:
        rr = run_resilient(argv, args.num_processes, max_failures=1,
                           env=_fabric_env(args.devices_per_process),
                           attempt_env=attempt_env, timeout_s=args.timeout)
    except FabricError as e:
        print(f"[recovery] FAILED: {e}")
        return 1

    for out in rr.result.outputs:
        sys.stdout.write(out)
    if len(rr.failures) != 1:
        print(f"[recovery] FAILED (expected exactly 1 scripted rank "
              f"failure, saw {len(rr.failures)})")
        return 1
    if not all("RECOVERY-OK" in o for o in rr.result.outputs):
        print("[recovery] FAILED (missing rank RECOVERY-OK marker)")
        return 1

    def rows(outputs, marker):
        found = []
        for out in outputs:
            found += [json.loads(ln[len(marker):])
                      for ln in out.splitlines() if ln.startswith(marker)]
        return found

    # The kill marker rides on the FAILED attempt's harvested outputs.
    kills = rows(getattr(rr.failures[0], "outputs", []), RECOVERY_KILL)
    resumed = rows(rr.result.outputs, RECOVERY_RESUMED)
    results = rows(rr.result.outputs, RECOVERY_MARKER)
    if not (kills and resumed and results):
        print(f"[recovery] FAILED (markers missing: kills={len(kills)} "
              f"resumed={len(resumed)} results={len(results)})")
        return 1
    kill = kills[-1]
    res0 = next(r for r in resumed if r["rank"] == 0)
    row = results[-1]

    # Solution-update units throughout: boundaries land at exact
    # multiples of ``every`` updates, so losing at most one interval
    # means recomputed <= every exactly.
    recomputed = int(kill["upd"]) - int(res0["upd"])
    detection_s = max(t_attempt[2] - float(kill["t"]), 0.0)
    respawn_s = max(float(res0["t"]) - t_attempt[2], 0.0)
    ok = (row["parity_bitwise"] and row["converged"]
          and 0 < recomputed <= args.every)
    summary = {
        "procs": args.num_processes,
        "devices_per_process": args.devices_per_process,
        "kill_rank": args.kill_rank,
        "kill_upd": int(kill["upd"]),
        "resumed_upd": int(res0["upd"]),
        "recomputed_iters": recomputed,
        "checkpoint_every": args.every,
        "detection_s": detection_s,
        "respawn_s": respawn_s,
        "attempts": rr.attempts,
        "iters": row["iters"],
        "parity_bitwise": int(bool(row["parity_bitwise"])),
        "converged": int(bool(row["converged"])),
    }
    print("RECOVERY-RESULT " + json.dumps(summary))
    print(f"[recovery] killed rank {args.kill_rank} at update "
          f"{kill['upd']}, detected + torn down in {detection_s:.1f}s, "
          f"respawned + resumed from update {res0['upd']} in "
          f"{respawn_s:.1f}s ({recomputed} updates recomputed <= "
          f"every={args.every}), resumed history bitwise vs local "
          f"oracle: {bool(row['parity_bitwise'])}")
    if not ok:
        print("[recovery] FAILED (acceptance gate)")
        return 1
    print(f"[recovery] {args.num_processes} processes x "
          f"{args.devices_per_process} devices: RECOVERY OK")
    return 0


def chaos(num_processes: int, devices_per_process: int) -> int:
    """Chaos launcher: every rank must emit the SAME ``CHAOS-GOV`` row —
    the governor fired identically (same count, same iterations, same
    bitwise history) on every process under the injected fault."""
    try:
        res = launch_fabric(
            lambda coord, k: [sys.executable, os.path.abspath(__file__),
                              "--coordinator", coord,
                              "--num-processes", str(num_processes),
                              "--process-id", str(k),
                              "--chaos-child"],
            num_processes, env=_fabric_env(devices_per_process),
            timeout_s=900)
    except FabricError as e:
        print(f"[launcher] FAILED: {e}")
        return 1
    for out in res.outputs:
        sys.stdout.write(out)
    if not all("CHAOS-OK" in o for o in res.outputs):
        print("[launcher] FAILED (missing rank CHAOS-OK marker)")
        return 1
    rows = []
    for k, out in enumerate(res.outputs):
        frag = [ln for ln in out.splitlines()
                if ln.startswith(CHAOS_MARKER)]
        if not frag:
            print(f"[launcher] FAILED (rank {k} emitted no chaos row)")
            return 1
        rows.append(frag[-1])
    if len(set(rows)) != 1:
        print("[launcher] FAILED (governor rows differ across ranks):")
        for k, r in enumerate(rows):
            print(f"  rank {k}: {r}")
        return 1
    row = json.loads(rows[0][len(CHAOS_MARKER):])
    print(f"[launcher] {num_processes} processes x "
          f"{devices_per_process} devices: CHAOS-GOV OK — governor "
          f"fired identically on every rank "
          f"({row['replacements']} replacement(s), "
          f"{row['iters']} iters, coordinator {res.coordinator})")
    return 0


def _fabric_env(devices_per_process: int) -> dict:
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count="
                  f"{devices_per_process}",
        JAX_PLATFORMS="cpu",
        JAX_CPU_COLLECTIVES_IMPLEMENTATION="gloo",
    )
    env.setdefault("PYTHONPATH", "src")
    return env


def launch(num_processes: int, devices_per_process: int) -> int:
    """Default parity mode: one fabric, assert every rank's OK marker."""
    try:
        res = launch_fabric(
            lambda coord, k: [sys.executable, os.path.abspath(__file__),
                              "--coordinator", coord,
                              "--num-processes", str(num_processes),
                              "--process-id", str(k)],
            num_processes, env=_fabric_env(devices_per_process),
            timeout_s=900)
    except FabricError as e:
        print(f"[launcher] FAILED: {e}")
        return 1
    for out in res.outputs:
        sys.stdout.write(out)
    if all("MULTIPROC-PARITY-OK" in o for o in res.outputs):
        print(f"[launcher] {num_processes} processes x "
              f"{devices_per_process} devices: PARITY OK "
              f"(coordinator {res.coordinator}, attempt {res.attempts})")
        return 0
    print("[launcher] FAILED")
    return 1


def study(args) -> int:
    """Strong-scaling sweep: fixed n, 1..N processes, staged vs
    monolithic, aggregated into the gated ``BENCH_scaling.json``."""
    procs_list = [int(p) for p in args.procs.split(",")]
    rows = []
    for p in procs_list:
        try:
            res = launch_fabric(
                lambda coord, k, _p=p: [
                    sys.executable, os.path.abspath(__file__),
                    "--coordinator", coord,
                    "--num-processes", str(_p),
                    "--process-id", str(k),
                    "--study-child",
                    "--nx", str(args.nx), "--ny", str(args.ny),
                    "--l", str(args.l), "--stages", str(args.stages),
                    "--repeats", str(args.repeats),
                    "--budget-lo", str(args.budget_lo),
                    "--budget-hi", str(args.budget_hi),
                ] + (["--emit-timelines"] if _p == max(procs_list) else []),
                p, env=_fabric_env(args.devices_per_process),
                timeout_s=args.timeout)
        except FabricError as e:
            print(f"[study] P={p} FAILED: {e}")
            return 1
        for out in res.outputs:
            sys.stdout.write(out)
        if not all("SCALING-OK" in o for o in res.outputs):
            print(f"[study] P={p} FAILED (missing rank OK marker)")
            return 1
        frag = [ln for ln in res.outputs[0].splitlines()
                if ln.startswith(STUDY_MARKER)]
        assert frag, "study child emitted no row"
        rows.append(json.loads(frag[-1][len(STUDY_MARKER):]))
        print(f"[study] P={p} done (coordinator {res.coordinator}, "
              f"attempt {res.attempts})")

    n = args.nx * args.ny
    multi = [r for r in rows if r["procs"] >= 2]
    payload = {
        "study": {
            "n": n, "nx": args.nx, "ny": args.ny, "l": args.l,
            "stages_requested": args.stages,
            "procs": procs_list,
            "devices_per_process": args.devices_per_process,
            "repeats": args.repeats,
            "iter_budgets": [args.budget_lo, args.budget_hi],
            "wall_clock_basis": (
                "compiled XLA CPU ranks over gloo TCP loopback; "
                "strong scaling at fixed n — NOT the paper's Cori "
                "fabric (see DESIGN.md §17 for what is and is not "
                "comparable)"),
        },
        "rows": rows,
        # Gated structural columns (deterministic on any machine):
        "scaling_parity_bitwise": int(all(r["parity_bitwise"]
                                          for r in rows)),
        "scaling_staged_allreduces_max": max(r["staged_allreduces"]
                                             for r in rows),
        "scaling_hops_per_window_min": min(
            (r["hops_per_window_min"] for r in multi), default=0),
        "scaling_staged_starts_max": max(
            (r["staged_starts_per_window_max"] for r in rows), default=0),
    }
    t1 = next((r for r in rows if r["procs"] == 1), None)
    for r in rows:
        p = r["procs"]
        payload[f"staged_iter_time_p{p}_s"] = r["staged_iter_time_s"]
        payload[f"monolithic_iter_time_p{p}_s"] = r["monolithic_iter_time_s"]
        if p >= 2:
            payload[f"staged_over_monolithic_p{p}"] = \
                r["staged_over_monolithic"]
        if t1 is not None:
            payload[f"staged_speedup_p{p}"] = \
                t1["staged_iter_time_s"] / r["staged_iter_time_s"]
            payload[f"monolithic_speedup_p{p}"] = \
                t1["monolithic_iter_time_s"] / r["monolithic_iter_time_s"]
    for k, v in payload.items():
        if k not in ("rows", "study"):
            print(f"{k}: {v}")
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--coordinator", type=str, default=None)
    ap.add_argument("--num-processes", type=int, default=2)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--devices-per-process", type=int, default=None)
    # ---- scaling study ----
    ap.add_argument("--study", action="store_true",
                    help="run the strong-scaling study (launcher mode)")
    ap.add_argument("--study-child", action="store_true")
    # ---- chaos drill (DESIGN.md §18) ----
    ap.add_argument("--chaos", action="store_true",
                    help="run the cross-process governed chaos drill "
                         "(launcher mode)")
    ap.add_argument("--chaos-child", action="store_true")
    # ---- recovery drill (DESIGN.md §19) ----
    ap.add_argument("--recovery", action="store_true",
                    help="run the kill-a-rank checkpoint/restore drill "
                         "(launcher mode)")
    ap.add_argument("--recovery-child", action="store_true")
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--every", type=int, default=20,
                    help="checkpoint interval (solution updates)")
    ap.add_argument("--kill-rank", type=int, default=1)
    ap.add_argument("--kill-at", type=int, default=35,
                    help="kill the rank at the first boundary reaching "
                         "this iteration")
    ap.add_argument("--attempt", type=int, default=1)
    ap.add_argument("--procs", type=str, default="1,2,4",
                    help="comma-separated process counts for --study")
    ap.add_argument("--nx", type=int, default=96)
    ap.add_argument("--ny", type=int, default=64)
    ap.add_argument("--l", type=int, default=2)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--budget-lo", type=int, default=20)
    ap.add_argument("--budget-hi", type=int, default=60)
    ap.add_argument("--timeout", type=float, default=900.0)
    ap.add_argument("--emit-timelines", action="store_true")
    ap.add_argument("--out", type=str, default="BENCH_scaling.json")
    args = ap.parse_args(argv)
    if args.study:
        if args.devices_per_process is None:
            args.devices_per_process = 1     # P ranks == P shards
        return study(args)
    if args.devices_per_process is None:
        small = (args.chaos or args.chaos_child
                 or args.recovery or args.recovery_child)
        args.devices_per_process = 2 if small else 4
    if args.process_id is None:
        if args.chaos:
            return chaos(args.num_processes, args.devices_per_process)
        if args.recovery:
            return recovery(args)
        return launch(args.num_processes, args.devices_per_process)
    if args.chaos_child:
        return chaos_child(args.coordinator, args.num_processes,
                           args.process_id)
    if args.recovery_child:
        return recovery_child(args.coordinator, args.num_processes,
                              args.process_id, args)
    if args.study_child:
        return study_child(args.coordinator, args.num_processes,
                           args.process_id, args)
    return child(args.coordinator, args.num_processes, args.process_id)


if __name__ == "__main__":
    sys.exit(main())
