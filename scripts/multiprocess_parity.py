#!/usr/bin/env python
"""Multi-controller parity check: the so-far-CI-untested ``multiprocess``
reduction backend, actually exercised across process boundaries.

Run with no arguments to LAUNCH: the script picks a free coordinator
port and spawns ``--num-processes`` copies of itself (default 2), each a
real ``jax.distributed`` controller with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — a 2-process x
4-device job whose solver mesh spans all 8 devices, so the fused
dot-block psum and the halo ppermutes genuinely cross the process
boundary (the paper's MPI world, DESIGN.md §3).

Each process runs the same program (multi-controller SPMD): classic CG
and p(l)-CG on a structured stencil AND an unstructured FEM SparseOp
(DESIGN.md §12), asserting residual-history parity against the
single-device ``local`` backend.  Replicated outputs (histories, iter
counts) are addressable on every process; the domain-decomposed ``x``
stays distributed and is validated through the recursive residual.

The run then exercises the STAGED-REDUCTION capability fallback
(DESIGN.md §14) across the real process boundary: requesting
``reduction="staged"`` from the multiprocess backend must set the
``reduction_fallback`` flag, run the monolithic cross-host psum instead
of the ppermute ladder, and reproduce the monolithic backend's residual
history BITWISE (same mesh, same arithmetic — the fallback is a wire
substitution, not a solver change).

CI wires this through tests/test_multiprocess.py (RUN_MULTIPROCESS=1).
"""

from __future__ import annotations

import argparse
import os
import socket
import subprocess
import sys


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def child(coordinator: str, num_processes: int, process_id: int) -> int:
    import jax

    # Cross-process CPU collectives need the gloo TCP backend (the
    # launcher also sets JAX_CPU_COLLECTIVES_IMPLEMENTATION for jax
    # versions that read the env var instead).
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except AttributeError:  # pragma: no cover - very old/new jax
        pass
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np

    from repro.core.chebyshev import shifts_for_operator
    from repro.linalg import Stencil2D5, random_fem_mesh, rcm_reorder
    from repro.parallel import get_backend

    be = get_backend(
        "multiprocess",
        coordinator_address=coordinator,
        num_processes=num_processes,
        process_id=process_id,
    )
    n_dev = be.n_shards
    assert jax.process_count() == num_processes, jax.process_count()
    assert n_dev == num_processes * jax.local_device_count(), n_dev
    print(f"[p{process_id}] {be.describe()}", flush=True)
    local = get_backend("local")

    problems = [
        ("stencil2d", Stencil2D5(32, 24)),
        ("fem-sparse", rcm_reorder(random_fem_mesh(0, 400))[0]),
    ]
    for name, op in problems:
        b = jnp.asarray(np.random.default_rng(7).standard_normal(op.n))
        sig = shifts_for_operator(op, 2)
        for method, kw in (("cg", {}), ("plcg", dict(l=2, sigmas=sig))):
            kw = dict(kw, tol=1e-8, maxit=800)
            res_m = be.solve(op, b, method=method, **kw)
            res_l = local.solve(op, b, method=method, **kw)
            hm = np.asarray(res_m.res_history)
            hl = np.asarray(res_l.res_history)
            n0 = float(res_l.norm0)
            m = (hm >= 0) & (hl >= 0)
            assert m.sum() > 5, (name, method, int(m.sum()))
            # Histories are norm0-normalized for comparison: Krylov
            # recurrences amplify reduction-order ULPs chaotically as the
            # residual shrinks (tests/test_distributed.py measures a 0.5
            # relative drift from a single ULP on b), so the contract is
            # a TIGHT head (pre-amplification — a wrong operator or halo
            # breaks here immediately) and a bounded tail.
            diff = np.abs(hm[m] - hl[m]) / n0
            assert diff[:10].max() < 1e-8, (name, method, diff[:10].max())
            assert diff.max() < 5e-2, (name, method, diff.max())
            d_it = abs(int(res_m.iters) - int(res_l.iters))
            assert d_it <= 5, (name, method, int(res_m.iters),
                               int(res_l.iters))
            assert bool(res_m.converged)
            print(f"[p{process_id}] {name}/{method}: iters "
                  f"{int(res_m.iters)} vs local {int(res_l.iters)}, "
                  f"max|dh|/norm0 {diff.max():.2e}", flush=True)

    # ---- staged-reduction capability fallback (DESIGN.md §14) -----------
    # Request the staged ring ladder across the real process boundary:
    # the backend must flag the downgrade and run the monolithic psum —
    # bitwise-identical histories to the plain multiprocess backend
    # (same mesh, same arithmetic; only the requested wire path differs).
    op = Stencil2D5(32, 24)
    b = jnp.asarray(np.random.default_rng(7).standard_normal(op.n))
    sig = shifts_for_operator(op, 2)
    import warnings

    from repro.obs.metrics import default_registry
    from repro.parallel.reduction import ReductionFallbackWarning

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        be_staged = get_backend(
            "multiprocess",
            coordinator_address=coordinator,
            num_processes=num_processes,
            process_id=process_id,
            reduction="staged",
            reduction_dtype=jnp.float32,
        )
    assert not type(be_staged).supports_staged_reduction
    assert be_staged.reduction_mode == "monolithic", be_staged.reduction_mode
    assert be_staged.reduction_fallback, "fallback reason must be recorded"
    assert be_staged.reduction_cfg is None
    # The downgrade must be LOUD (DESIGN.md §16): a structured warning
    # at construction plus a gauge on the default metrics registry.
    assert any(isinstance(w.message, ReductionFallbackWarning)
               for w in caught), [str(w.message) for w in caught]
    g = default_registry().get("backend_reduction_fallback")
    assert g is not None
    assert g.value(labels={"backend": "multiprocess"}) == 1.0
    kw = dict(method="plcg", l=2, sigmas=sig, tol=1e-8, maxit=800)
    res_s = be_staged.solve(op, b, **kw)
    res_m = be.solve(op, b, **kw)
    hs, hm2 = np.asarray(res_s.res_history), np.asarray(res_m.res_history)
    assert np.array_equal(hs, hm2), np.abs(hs - hm2).max()
    assert bool(res_s.converged)
    print(f"[p{process_id}] staged request -> monolithic fallback "
          f"(flagged: {be_staged.reduction_fallback!r}), history bitwise "
          f"vs monolithic", flush=True)

    # ---- instrumented cross-process solve + timeline export (§16) -------
    # Every process runs the SAME instrumented solve (telemetry values
    # are post-psum replicated scalars — no new collectives cross the
    # wire) and exports its own Chrome-trace JSON; the launcher/CI pick
    # the files up as artifacts.
    from repro.obs import Timeline, telemetry_track

    tl = Timeline()
    tl.name_thread(1, 1, "cross-process solve phases")
    with tl.span("plcg[instrumented, cross-process]"):
        res_t = be.solve(op, b, method="plcg", l=2, sigmas=sig, tol=1e-8,
                         maxit=800, telemetry_cap=128)
        jax.block_until_ready(res_t.res_history)
    assert res_t.telemetry is not None
    tel = np.asarray(res_t.telemetry)
    assert (tel[:, 0] >= 0).any(), "telemetry ring never written"
    tl.merge(telemetry_track(res_t.telemetry, l=2))
    tl.meta["parity"] = {
        "process_id": process_id, "num_processes": num_processes,
        "backend": be.name, "reduction_mode": be.reduction_mode,
    }
    path = tl.save(f"TIMELINE_parity_proc{process_id}.json")
    print(f"[p{process_id}] timeline -> {path}", flush=True)

    print(f"[p{process_id}] MULTIPROC-PARITY-OK", flush=True)
    return 0


def launch(num_processes: int, devices_per_process: int) -> int:
    coordinator = f"127.0.0.1:{free_port()}"
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count="
                  f"{devices_per_process}",
        JAX_PLATFORMS="cpu",
        JAX_CPU_COLLECTIVES_IMPLEMENTATION="gloo",
    )
    env.setdefault("PYTHONPATH", "src")
    procs = [
        subprocess.Popen(
            [sys.executable, os.path.abspath(__file__),
             "--coordinator", coordinator,
             "--num-processes", str(num_processes),
             "--process-id", str(k)],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True,
        )
        for k in range(num_processes)
    ]
    outs = []
    code = 0
    for k, p in enumerate(procs):
        try:
            out, _ = p.communicate(timeout=900)
        except subprocess.TimeoutExpired:
            p.kill()
            out, _ = p.communicate()
            out += "\n[launcher] TIMEOUT"
        outs.append(out)
        code |= p.returncode if p.returncode is not None else 1
        sys.stdout.write(out)
    if code == 0 and all("MULTIPROC-PARITY-OK" in o for o in outs):
        print(f"[launcher] {num_processes} processes x "
              f"{devices_per_process} devices: PARITY OK")
        return 0
    print("[launcher] FAILED")
    return 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--coordinator", type=str, default=None)
    ap.add_argument("--num-processes", type=int, default=2)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--devices-per-process", type=int, default=4)
    args = ap.parse_args(argv)
    if args.process_id is None:
        return launch(args.num_processes, args.devices_per_process)
    return child(args.coordinator, args.num_processes, args.process_id)


if __name__ == "__main__":
    sys.exit(main())
