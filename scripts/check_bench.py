#!/usr/bin/env python
"""Benchmark regression gate: compare a freshly produced BENCH_*.json
against the committed baseline and FAIL (exit 1) when a gated metric
regresses by more than the allowed fraction.

The serve-bench artifact stopped being informational in ISSUE 3: CI now
runs the benchmark, then gates on the committed baseline —
``slab_speedup_vs_sequential`` may not drop more than 20%.  The same
gate covers the unstructured-SpMV bench (``benchmarks/spmv_bench.py``
-> BENCH_spmv.json), whose gated metrics are *structural* (ELL
occupancy, halo fraction) and therefore immune to CI timing noise.

Usage:
    python scripts/check_bench.py --baseline BENCH_serve.json \
        --fresh BENCH_serve_fresh.json \
        --gate slab_speedup_vs_sequential:0.20 [--gate key:frac ...]

    python scripts/check_bench.py --selftest
        # proves the gate trips: injects a >20% regression and asserts
        # a nonzero problem count (CI runs this so a silently broken
        # gate fails the build, not a future regression).

Gate semantics: for higher-is-better metrics (the default), fail when
fresh < (1 - frac) * baseline.  Prefix the key with ``-`` for
lower-is-better metrics (latencies): fail when fresh > (1 + frac) *
baseline.  Missing keys fail loudly — a gate that cannot see its metric
is itself a regression.
"""

from __future__ import annotations

import argparse
import json
import sys


def parse_gate(spec: str) -> tuple[str, float, bool]:
    """'key:frac' -> (key, frac, higher_is_better)."""
    key, _, frac = spec.partition(":")
    if not frac:
        raise SystemExit(f"bad --gate {spec!r} (want key:frac)")
    higher = not key.startswith("-")
    return key.lstrip("-"), float(frac), higher


def check(baseline: dict, fresh: dict,
          gates: list[tuple[str, float, bool]], verbose: bool = True) -> int:
    """Number of violated gates (0 == within budget)."""
    problems = 0
    for key, frac, higher in gates:
        if key not in baseline or key not in fresh:
            problems += 1
            if verbose:
                missing = [w for w, d in (("baseline", baseline),
                                          ("fresh", fresh)) if key not in d]
                print(f"check_bench: GATE {key}: missing from "
                      f"{'/'.join(missing)} — cannot gate")
            continue
        base, cur = float(baseline[key]), float(fresh[key])
        if higher:
            floor = (1.0 - frac) * base
            ok = cur >= floor
            verdict = f"{cur:.4g} vs floor {floor:.4g} (baseline {base:.4g})"
        else:
            ceil = (1.0 + frac) * base
            ok = cur <= ceil
            verdict = f"{cur:.4g} vs ceiling {ceil:.4g} (baseline {base:.4g})"
        if not ok:
            problems += 1
        if verbose:
            print(f"check_bench: {'ok  ' if ok else 'FAIL'} {key}: {verdict}")
    return problems


def selftest() -> int:
    """The gate must trip on an injected >20% regression, pass inside
    the budget, and fail on a missing key."""
    base = {"slab_speedup_vs_sequential": 6.0, "latency_p99_s": 0.10}
    gates = [("slab_speedup_vs_sequential", 0.20, True)]
    assert check(base, {"slab_speedup_vs_sequential": 6.3}, gates,
                 verbose=False) == 0, "improvement must pass"
    assert check(base, {"slab_speedup_vs_sequential": 4.9}, gates,
                 verbose=False) == 0, "18% drop is inside the 20% budget"
    assert check(base, {"slab_speedup_vs_sequential": 4.7}, gates,
                 verbose=False) == 1, "22% drop must fail"
    assert check(base, {}, gates, verbose=False) == 1, \
        "missing metric must fail"
    lat = [("latency_p99_s", 0.5, False)]
    assert check(base, {"latency_p99_s": 0.14}, lat, verbose=False) == 0
    assert check(base, {"latency_p99_s": 0.16}, lat, verbose=False) == 1, \
        "lower-is-better ceiling must fail"
    print("check_bench: selftest OK — injected >20% regression trips "
          "the gate")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", type=str)
    ap.add_argument("--fresh", type=str)
    ap.add_argument("--gate", action="append", default=[],
                    help="key:frac (prefix key with - for lower-is-better)")
    ap.add_argument("--selftest", action="store_true")
    args = ap.parse_args(argv)
    if args.selftest:
        return selftest()
    if not (args.baseline and args.fresh and args.gate):
        ap.error("--baseline, --fresh and at least one --gate required")
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    gates = [parse_gate(g) for g in args.gate]
    return 1 if check(baseline, fresh, gates) else 0


if __name__ == "__main__":
    sys.exit(main())
