#!/usr/bin/env python
"""Benchmark regression gate: compare a freshly produced BENCH_*.json
against the committed baseline and FAIL (exit 1) when a gated metric
regresses by more than the allowed fraction.

The serve-bench artifact stopped being informational in ISSUE 3: CI now
runs the benchmark, then gates on the committed baseline —
``slab_speedup_vs_sequential`` may not drop more than 20%.  The same
gate covers the unstructured-SpMV bench (``benchmarks/spmv_bench.py``
-> BENCH_spmv.json), whose gated metrics are *structural* (ELL
occupancy, halo fraction) and therefore immune to CI timing noise.

Usage:
    python scripts/check_bench.py --baseline BENCH_serve.json \
        --fresh BENCH_serve_fresh.json \
        --gate slab_speedup_vs_sequential:0.20 [--gate key:frac ...]

    python scripts/check_bench.py --selftest
        # proves the gate trips: injects a >20% regression and asserts
        # a nonzero problem count (CI runs this so a silently broken
        # gate fails the build, not a future regression).

Gate semantics: for higher-is-better metrics (the default), fail when
fresh < (1 - frac) * baseline.  Prefix the key with ``-`` for
lower-is-better metrics (latencies): fail when fresh > (1 + frac) *
baseline.  Missing keys fail loudly — a gate that cannot see its metric
is itself a regression.

Structural RATIO gates (ISSUE 4) constrain two metrics of the SAME
fresh file against each other instead of against a baseline::

    python scripts/check_bench.py --fresh BENCH_iter.json \
        --ratio-gate fused_bytes_per_iter:unfused_bytes_per_iter:0.6

fails when fresh[num] > max_ratio * fresh[den].  Both fused-iteration
gates are deterministic shape properties machine noise cannot move
(DESIGN.md §13), and they catch DIFFERENT regressions: the 0.6x gate
pairs the fused path's custom-call accounting (a function of the slab
layout) against the measured unfused traffic — it trips when the state
slab grows or the unfused path sheds passes without the kernel
following; the companion 1.15x gate on
``fused_bytes_interpret_measured`` is fully MEASURED (cost_analysis of
the interpret-lowered kernel) — it trips when someone adds an
accidental extra slab pass INSIDE the kernel body.  ``--baseline`` is
not needed for ratio-only runs.

Skip payloads (ISSUE 8, the opt-in compiled lane): a bench invoked with
``--kernel-mode compiled`` on a CPU-only runner writes ``{"skipped":
true, "reason": ...}`` instead of numbers (``benchmarks.lane``).  With
``--skip-ok`` this checker prints the recorded reason and exits 0 — the
lane stays green while stating loudly that nothing was measured.
WITHOUT the flag a skip payload fails immediately: a gate fed a skip
marker where it expected measurements must never pass silently.
"""

from __future__ import annotations

import argparse
import json
import sys


def parse_gate(spec: str) -> tuple[str, float, bool]:
    """'key:frac' -> (key, frac, higher_is_better)."""
    key, _, frac = spec.partition(":")
    if not frac:
        raise SystemExit(f"bad --gate {spec!r} (want key:frac)")
    higher = not key.startswith("-")
    return key.lstrip("-"), float(frac), higher


def check(baseline: dict, fresh: dict,
          gates: list[tuple[str, float, bool]], verbose: bool = True) -> int:
    """Number of violated gates (0 == within budget)."""
    problems = 0
    for key, frac, higher in gates:
        if key not in baseline or key not in fresh:
            problems += 1
            if verbose:
                missing = [w for w, d in (("baseline", baseline),
                                          ("fresh", fresh)) if key not in d]
                print(f"check_bench: GATE {key}: missing from "
                      f"{'/'.join(missing)} — cannot gate")
            continue
        base, cur = float(baseline[key]), float(fresh[key])
        if higher:
            floor = (1.0 - frac) * base
            ok = cur >= floor
            verdict = f"{cur:.4g} vs floor {floor:.4g} (baseline {base:.4g})"
        else:
            ceil = (1.0 + frac) * base
            ok = cur <= ceil
            verdict = f"{cur:.4g} vs ceiling {ceil:.4g} (baseline {base:.4g})"
        if not ok:
            problems += 1
        if verbose:
            print(f"check_bench: {'ok  ' if ok else 'FAIL'} {key}: {verdict}")
    return problems


def parse_ratio_gate(spec: str) -> tuple[str, str, float]:
    """'num_key:den_key:max_ratio' -> (num, den, max_ratio)."""
    parts = spec.split(":")
    if len(parts) != 3:
        raise SystemExit(
            f"bad --ratio-gate {spec!r} (want num_key:den_key:max_ratio)")
    return parts[0], parts[1], float(parts[2])


def check_ratios(fresh: dict, gates: list[tuple[str, str, float]],
                 verbose: bool = True) -> int:
    """Number of violated ratio gates (0 == within budget)."""
    problems = 0
    for num, den, max_ratio in gates:
        missing = [k for k in (num, den) if k not in fresh]
        if missing:
            problems += 1
            if verbose:
                print(f"check_bench: RATIO GATE {num}/{den}: missing "
                      f"{'/'.join(missing)} — cannot gate")
            continue
        nv, dv = float(fresh[num]), float(fresh[den])
        ratio = nv / dv if dv else float("inf")
        ok = ratio <= max_ratio
        if not ok:
            problems += 1
        if verbose:
            print(f"check_bench: {'ok  ' if ok else 'FAIL'} {num}/{den}: "
                  f"{ratio:.4g} vs max {max_ratio:.4g} "
                  f"({nv:.4g} / {dv:.4g})")
    return problems


def handle_skip(fresh: dict, skip_ok: bool,
                verbose: bool = True) -> int | None:
    """None when ``fresh`` holds real measurements; otherwise the exit
    code for a skip payload (0 under --skip-ok, 1 without)."""
    if not fresh.get("skipped"):
        return None
    reason = fresh.get("reason", "no reason recorded")
    if skip_ok:
        if verbose:
            print(f"check_bench: SKIPPED (allowed by --skip-ok): {reason}")
        return 0
    if verbose:
        print(f"check_bench: FAIL — fresh payload is a skip marker, not "
              f"measurements ({reason}); pass --skip-ok only on lanes "
              f"where skipping is legitimate")
    return 1


def selftest() -> int:
    """The gate must trip on an injected >20% regression, pass inside
    the budget, and fail on a missing key."""
    base = {"slab_speedup_vs_sequential": 6.0, "latency_p99_s": 0.10}
    gates = [("slab_speedup_vs_sequential", 0.20, True)]
    assert check(base, {"slab_speedup_vs_sequential": 6.3}, gates,
                 verbose=False) == 0, "improvement must pass"
    assert check(base, {"slab_speedup_vs_sequential": 4.9}, gates,
                 verbose=False) == 0, "18% drop is inside the 20% budget"
    assert check(base, {"slab_speedup_vs_sequential": 4.7}, gates,
                 verbose=False) == 1, "22% drop must fail"
    assert check(base, {}, gates, verbose=False) == 1, \
        "missing metric must fail"
    lat = [("latency_p99_s", 0.5, False)]
    assert check(base, {"latency_p99_s": 0.14}, lat, verbose=False) == 0
    assert check(base, {"latency_p99_s": 0.16}, lat, verbose=False) == 1, \
        "lower-is-better ceiling must fail"
    # Ratio gate (ISSUE 4): fused bytes must stay <= 0.6x unfused.
    rg = [("fused_bytes_per_iter", "unfused_bytes_per_iter", 0.6)]
    ok_iter = {"fused_bytes_per_iter": 15.0, "unfused_bytes_per_iter": 60.0}
    bad_iter = {"fused_bytes_per_iter": 40.0, "unfused_bytes_per_iter": 60.0}
    assert check_ratios(ok_iter, rg, verbose=False) == 0, \
        "0.25x ratio is inside the 0.6x budget"
    assert check_ratios(bad_iter, rg, verbose=False) == 1, \
        "0.67x ratio must fail the 0.6x gate"
    assert check_ratios({}, rg, verbose=False) == 1, \
        "missing ratio metric must fail"
    # Staged-reduction gates (ISSUE 5, BENCH_reduce.json).  The fp32
    # hop-payload ratio gate: the mixed-precision ladder must keep its
    # per-hop wire bytes <= 0.55x the fp64 monolithic payload.
    rr = [("staged_hop_payload_bytes_fp32",
           "monolithic_payload_bytes_fp64", 0.55)]
    ok_red = {"staged_hop_payload_bytes_fp32": 20.0,
              "monolithic_payload_bytes_fp64": 40.0}
    bad_red = {"staged_hop_payload_bytes_fp32": 24.0,
               "monolithic_payload_bytes_fp64": 40.0}
    assert check_ratios(ok_red, rr, verbose=False) == 0, \
        "0.5x fp32 hop payload is inside the 0.55x budget"
    assert check_ratios(bad_red, rr, verbose=False) == 1, \
        "0.6x fp32 hop payload must fail the 0.55x gate"
    # The zero-allreduce gate: lower-is-better against a committed
    # baseline of 0 — ANY all-reduce sneaking back into the staged dot
    # block trips it (ceiling = (1+frac)*0 = 0), and the hops-per-window
    # floor gate: the ladder may never thin below the committed minimum.
    red_base = {"staged_dotblock_allreduces": 0, "hops_per_window_min": 4}
    red_gates = [("staged_dotblock_allreduces", 0.0, False),
                 ("hops_per_window_min", 0.0, True)]
    assert check(red_base, {"staged_dotblock_allreduces": 0,
                            "hops_per_window_min": 4},
                 red_gates, verbose=False) == 0
    assert check(red_base, {"staged_dotblock_allreduces": 1,
                            "hops_per_window_min": 4},
                 red_gates, verbose=False) == 1, \
        "one all-reduce in the staged dot block must fail"
    assert check(red_base, {"staged_dotblock_allreduces": 0,
                            "hops_per_window_min": 3},
                 red_gates, verbose=False) == 1, \
        "a thinned hop window must fail the floor gate"
    # Open-loop replay gates (ISSUE 6, BENCH_serve.json; DESIGN.md §15).
    # Every replay_* metric is virtual-clock arithmetic — bitwise
    # deterministic across machines — so the budgets are tight: goodput
    # floor, p99 ceiling, slot-utilization floor, and the HLO
    # reduction-starts ceiling (a SECOND reduction handle per iteration
    # sneaking into the slab schedule fails at +0 tolerance).
    rp_base = {"replay_goodput_per_s": 100.0, "replay_p99_s": 0.050,
               "replay_slot_utilization": 0.85,
               "replay_reduction_starts_per_iter_max": 1}
    rp_gates = [("replay_goodput_per_s", 0.10, True),
                ("replay_p99_s", 0.10, False),
                ("replay_slot_utilization", 0.05, True),
                ("replay_reduction_starts_per_iter_max", 0.0, False)]
    assert check(rp_base, dict(rp_base), rp_gates, verbose=False) == 0, \
        "identical replay metrics must pass every replay gate"
    assert check(rp_base, dict(rp_base, replay_goodput_per_s=85.0),
                 rp_gates, verbose=False) == 1, \
        "a 15% goodput drop must fail the 10% floor"
    assert check(rp_base, dict(rp_base, replay_p99_s=0.060),
                 rp_gates, verbose=False) == 1, \
        "a 20% p99 blowup must fail the 10% ceiling"
    assert check(rp_base, dict(rp_base, replay_slot_utilization=0.79),
                 rp_gates, verbose=False) == 1, \
        "a slot-utilization slump must fail the 5% floor"
    assert check(rp_base,
                 dict(rp_base, replay_reduction_starts_per_iter_max=2),
                 rp_gates, verbose=False) == 1, \
        "a second reduction handle per iteration must fail at +0"
    # ... and the structural ratio: drain-to-empty serving must stay
    # strictly worse than continuous injection on the same trace.
    rru = [("replay_slot_utilization_drain", "replay_slot_utilization",
            0.95)]
    assert check_ratios({"replay_slot_utilization_drain": 0.60,
                         "replay_slot_utilization": 0.90},
                        rru, verbose=False) == 0
    assert check_ratios({"replay_slot_utilization_drain": 0.88,
                         "replay_slot_utilization": 0.90},
                        rru, verbose=False) == 1, \
        "drain utilization within 95% of continuous must fail"
    # Observability gates (ISSUE 7, BENCH_serve.json; DESIGN.md §16).
    # The instrumented virtual-time replay may cost at most 5% makespan
    # over the plain one (it should cost exactly 0: the ring adds no
    # collectives and no host syncs), the instrumented schedule must
    # keep ONE reduction start per iteration at +0 tolerance, and the
    # ring row must stay under 5% of the modeled per-iteration HBM
    # traffic.
    ob = [("replay_makespan_instrumented_s", "replay_makespan_s", 1.05)]
    assert check_ratios({"replay_makespan_instrumented_s": 0.100,
                         "replay_makespan_s": 0.100},
                        ob, verbose=False) == 0, \
        "zero instrumentation overhead must pass"
    assert check_ratios({"replay_makespan_instrumented_s": 0.107,
                         "replay_makespan_s": 0.100},
                        ob, verbose=False) == 1, \
        "a 7% instrumented-makespan blowup must fail the 5% gate"
    ob_base = {"instrumented_reduction_starts_per_iter_max": 1,
               "telemetry_iteration_bytes_ratio": 0.05}
    ob_gates = [("instrumented_reduction_starts_per_iter_max", 0.0, False),
                ("telemetry_iteration_bytes_ratio", 0.0, False)]
    assert check(ob_base, dict(ob_base), ob_gates, verbose=False) == 0
    assert check(ob_base,
                 dict(ob_base, instrumented_reduction_starts_per_iter_max=2),
                 ob_gates, verbose=False) == 1, \
        "a reduction handle added by instrumentation must fail at +0"
    assert check(ob_base,
                 dict(ob_base, telemetry_iteration_bytes_ratio=0.08),
                 ob_gates, verbose=False) == 1, \
        "a fattened telemetry row must fail the byte-ratio ceiling"
    # Strong-scaling study gates (ISSUE 8, BENCH_scaling.json; DESIGN.md
    # §17).  The deterministic columns gate at zero tolerance: the
    # cross-process ladder must stay BITWISE against the virtual-shards
    # oracle at every P (floor on the 0/1 parity flag), the compiled
    # staged solve must carry zero dot-block all-reduces at any P
    # (ceiling on the max count), and the hop schedule may never thin
    # below the committed per-window floor.
    sc_base = {"scaling_parity_bitwise": 1,
               "scaling_staged_allreduces_max": 0,
               "scaling_hops_per_window_min": 1}
    sc_gates = [("scaling_parity_bitwise", 0.0, True),
                ("scaling_staged_allreduces_max", 0.0, False),
                ("scaling_hops_per_window_min", 0.0, True)]
    assert check(sc_base, dict(sc_base), sc_gates, verbose=False) == 0
    assert check(sc_base, dict(sc_base, scaling_parity_bitwise=0),
                 sc_gates, verbose=False) == 1, \
        "a non-bitwise scaling row must fail the parity floor"
    assert check(sc_base, dict(sc_base, scaling_staged_allreduces_max=1),
                 sc_gates, verbose=False) == 1, \
        "an all-reduce in any scaling row must fail at +0"
    assert check(sc_base, dict(sc_base, scaling_hops_per_window_min=0),
                 sc_gates, verbose=False) == 1, \
        "a hopless staged window at P>=2 must fail the floor"
    # ... and the wall-clock ratio gates: staged <= monolithic
    # seconds/iteration at P=2 (the fabric's latency-bound point), and
    # the 2.5x hop-serialization ceiling at P=4 (DESIGN.md §17: on a
    # core-starved container every collective costs a scheduler slice,
    # so the P-1=3-hop ladder pays up to ~3x the one-psum path instead
    # of winning; 2.5 sits between the ~1.9x measured on a single-core
    # container and that fully-serialized hop-count bound).
    sr = [("staged_iter_time_p2_s", "monolithic_iter_time_p2_s", 1.0),
          ("staged_iter_time_p4_s", "monolithic_iter_time_p4_s", 2.5)]
    ok_sc = {"staged_iter_time_p2_s": 0.9, "monolithic_iter_time_p2_s": 1.0,
             "staged_iter_time_p4_s": 1.9, "monolithic_iter_time_p4_s": 1.0}
    assert check_ratios(ok_sc, sr, verbose=False) == 0
    assert check_ratios(dict(ok_sc, staged_iter_time_p2_s=1.1),
                        sr, verbose=False) == 1, \
        "staged slower than monolithic at P=2 must fail"
    assert check_ratios(dict(ok_sc, staged_iter_time_p4_s=2.6),
                        sr, verbose=False) == 1, \
        "a P=4 ladder past the 2.5x serialization ceiling must fail"
    # Stability-governor gates (ISSUE 9, BENCH_stability.json; DESIGN.md
    # §18).  The recovery demonstration gates at zero tolerance on its
    # deterministic 0/1 columns: governed-recovered floor (the governed
    # stable solver must reach tol under the seeded fault), ungoverned-
    # stagnated floor (the fault must still defeat the ungoverned
    # solver — otherwise the bench demonstrates nothing), the typed-
    # ladder floor, and the sacred reduction-starts ceilings (a governed
    # compile may never issue a second pipelined reduction start per
    # iteration, nor any staged dot-block all-reduce).
    st_base = {"stability_governed_recovered": 1,
               "stability_ungoverned_stagnated": 1,
               "stability_ladder_typed_error": 1,
               "stability_reduction_starts_per_iter_max": 1,
               "stability_staged_starts_per_iter_max": 1,
               "stability_staged_allreduces": 0,
               "stability_recovery_ratio": 2600.0,
               "stability_governor_replacements": 12}
    st_gates = [("stability_governed_recovered", 0.0, True),
                ("stability_ungoverned_stagnated", 0.0, True),
                ("stability_ladder_typed_error", 0.0, True),
                ("stability_reduction_starts_per_iter_max", 0.0, False),
                ("stability_staged_starts_per_iter_max", 0.0, False),
                ("stability_staged_allreduces", 0.0, False),
                ("stability_recovery_ratio", 0.5, True),
                ("stability_governor_replacements", 0.5, True)]
    assert check(st_base, dict(st_base), st_gates, verbose=False) == 0, \
        "identical stability metrics must pass every stability gate"
    assert check(st_base, dict(st_base, stability_governed_recovered=0),
                 st_gates, verbose=False) == 1, \
        "a failed governed recovery must fail the floor"
    assert check(st_base, dict(st_base, stability_ungoverned_stagnated=0),
                 st_gates, verbose=False) == 1, \
        "an ungoverned solve that no longer stagnates must fail (the " \
        "bench would be demonstrating nothing)"
    assert check(st_base, dict(st_base, stability_ladder_typed_error=0),
                 st_gates, verbose=False) == 1, \
        "silent non-convergence from the ladder must fail"
    assert check(st_base,
                 dict(st_base, stability_reduction_starts_per_iter_max=2),
                 st_gates, verbose=False) == 1, \
        "a second reduction start in a governed compile must fail at +0"
    assert check(st_base,
                 dict(st_base, stability_staged_starts_per_iter_max=2),
                 st_gates, verbose=False) == 1, \
        "a second staged hop-0 start per window must fail at +0"
    assert check(st_base, dict(st_base, stability_staged_allreduces=1),
                 st_gates, verbose=False) == 1, \
        "a staged dot-block all-reduce under the governor must fail at +0"
    assert check(st_base, dict(st_base, stability_recovery_ratio=1200.0),
                 st_gates, verbose=False) == 1, \
        "a halved attainable-accuracy gap must fail the 50% floor"
    assert check(st_base, dict(st_base, stability_governor_replacements=5),
                 st_gates, verbose=False) == 1, \
        "a governor that stopped firing must fail the replacement floor"
    # ... and the accuracy ratio gates within the fresh file: governed
    # final TRUE residual <= tol, ungoverned >= 100x tol.
    st_r = [("stability_governed_true_rel", "stability_tol", 1.0),
            ("stability_tol", "stability_ungoverned_true_rel", 0.01)]
    ok_st = {"stability_governed_true_rel": 7.7e-6, "stability_tol": 1e-5,
             "stability_ungoverned_true_rel": 2.0e-2}
    assert check_ratios(ok_st, st_r, verbose=False) == 0
    assert check_ratios(dict(ok_st, stability_governed_true_rel=1.2e-5),
                        st_r, verbose=False) == 1, \
        "a governed TRUE residual above tol must fail"
    assert check_ratios(dict(ok_st, stability_ungoverned_true_rel=5e-4),
                        st_r, verbose=False) == 1, \
        "an ungoverned residual within 100x of tol must fail (the " \
        "demonstration margin collapsed)"
    # Elastic-recovery gates (ISSUE 10, BENCH_recovery.json; DESIGN.md
    # §19).  Deterministic 0/1 columns gate at zero tolerance: the
    # cross-process drill's resumed history must stay BITWISE against
    # the never-killed oracle, the resumed solve must converge, the
    # single-process resume must stay bitwise, the serve replay must
    # stay deterministic with all healed columns converged and nothing
    # shed.  Counter floors/ceilings pin the healing path itself: one
    # worker death, four resubmissions, zero sheds with budget — and all
    # four shed (typed, finite) when the budget is zero.
    rc_base = {"recovery_parity_bitwise": 1, "recovery_converged": 1,
               "recovery_resume_bitwise": 1,
               "recovery_serve_worker_deaths": 1,
               "recovery_serve_resubmitted": 4,
               "recovery_serve_shed": 0,
               "recovery_serve_all_converged": 1,
               "recovery_serve_deterministic_replay": 1,
               "recovery_serve_exhausted_shed": 4}
    rc_gates = [("recovery_parity_bitwise", 0.0, True),
                ("recovery_converged", 0.0, True),
                ("recovery_resume_bitwise", 0.0, True),
                ("recovery_serve_worker_deaths", 0.0, False),
                ("recovery_serve_resubmitted", 0.0, True),
                ("recovery_serve_shed", 0.0, False),
                ("recovery_serve_all_converged", 0.0, True),
                ("recovery_serve_deterministic_replay", 0.0, True),
                ("recovery_serve_exhausted_shed", 0.0, True)]
    assert check(rc_base, dict(rc_base), rc_gates, verbose=False) == 0, \
        "identical recovery metrics must pass every recovery gate"
    assert check(rc_base, dict(rc_base, recovery_parity_bitwise=0),
                 rc_gates, verbose=False) == 1, \
        "a non-bitwise resumed drill history must fail the parity floor"
    assert check(rc_base, dict(rc_base, recovery_resume_bitwise=0),
                 rc_gates, verbose=False) == 1, \
        "a perturbed single-process resume must fail the floor"
    assert check(rc_base, dict(rc_base, recovery_serve_shed=1),
                 rc_gates, verbose=False) == 1, \
        "a shed request with retry budget left must fail at +0"
    assert check(rc_base, dict(rc_base, recovery_serve_worker_deaths=2),
                 rc_gates, verbose=False) == 1, \
        "a second worker death in the one-fault replay must fail"
    assert check(rc_base,
                 dict(rc_base, recovery_serve_deterministic_replay=0),
                 rc_gates, verbose=False) == 1, \
        "a nondeterministic fault replay must fail the floor"
    assert check(rc_base, dict(rc_base, recovery_serve_exhausted_shed=3),
                 rc_gates, verbose=False) == 1, \
        "a zero-budget replay that fails to shed every column must fail"
    # ... and the §19 rework bound as a within-file ratio: a kill may
    # cost at most ONE checkpoint interval of recomputed updates.
    rc_r = [("recovery_recomputed_iters", "recovery_checkpoint_every", 1.0)]
    assert check_ratios({"recovery_recomputed_iters": 20,
                         "recovery_checkpoint_every": 20},
                        rc_r, verbose=False) == 0, \
        "recomputed == every is exactly the bound — must pass"
    assert check_ratios({"recovery_recomputed_iters": 23,
                         "recovery_checkpoint_every": 20},
                        rc_r, verbose=False) == 1, \
        "recomputing past one checkpoint interval must fail (the " \
        "boundary landed off the update grid)"
    # Skip-payload handling (the opt-in compiled lane): a skip marker
    # passes ONLY under --skip-ok; real payloads ignore the flag.
    skipped = {"skipped": True, "reason": "no accelerator",
               "requested_kernel_mode": "compiled", "jax_backend": "cpu"}
    assert handle_skip(skipped, skip_ok=True, verbose=False) == 0, \
        "--skip-ok must accept a skip payload"
    assert handle_skip(skipped, skip_ok=False, verbose=False) == 1, \
        "a skip payload without --skip-ok must fail"
    assert handle_skip(ok_sc, skip_ok=True, verbose=False) is None, \
        "real measurements must fall through to the gates"
    print("check_bench: selftest OK — injected >20% regression, a >0.6x "
          "fused/unfused bytes ratio, a >0.55x fp32 hop payload, a "
          "staged all-reduce, a thinned hop window, every replay "
          "gate (goodput floor, p99 ceiling, utilization floor, "
          "reduction-starts ceiling, drain/continuous ratio), and every "
          "observability gate (instrumented makespan ratio, instrumented "
          "starts ceiling, telemetry byte ratio), every scaling-study "
          "gate (bitwise-parity floor, zero-all-reduce ceiling, hop "
          "floor, staged<=monolithic at P=2, the P=4 serialization "
          "ceiling), every stability gate (governed-recovered floor, "
          "ungoverned-stagnated floor, typed-ladder floor, the governed "
          "reduction-starts and staged all-reduce ceilings, the "
          "recovery-ratio and replacement floors, the governed<=tol and "
          "ungoverned>=100x-tol accuracy ratios), every elastic-recovery "
          "gate (drill bitwise-parity and convergence floors, the "
          "single-process resume floor, the serve death/resubmit/shed "
          "counters, the deterministic-replay floor, the zero-budget "
          "shed floor, the one-interval rework ratio), and the "
          "skip-payload rules (pass only under --skip-ok) all trip")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", type=str)
    ap.add_argument("--fresh", type=str)
    ap.add_argument("--gate", action="append", default=[],
                    help="key:frac (prefix key with - for lower-is-better)")
    ap.add_argument("--ratio-gate", action="append", default=[],
                    help="num_key:den_key:max_ratio (within --fresh)")
    ap.add_argument("--skip-ok", action="store_true",
                    help="exit 0 when --fresh is a machine-readable skip "
                         "payload (the opt-in compiled lane on CPU-only "
                         "runners); without this flag a skip payload "
                         "fails loudly")
    ap.add_argument("--selftest", action="store_true")
    args = ap.parse_args(argv)
    if args.selftest:
        return selftest()
    if not args.fresh or not (args.gate or args.ratio_gate):
        ap.error("--fresh and at least one --gate/--ratio-gate required")
    if args.gate and not args.baseline:
        ap.error("--gate needs --baseline (use --ratio-gate for "
                 "baseline-free structural gates)")
    with open(args.fresh) as f:
        fresh = json.load(f)
    skip_code = handle_skip(fresh, args.skip_ok)
    if skip_code is not None:
        return skip_code
    problems = 0
    if args.gate:
        with open(args.baseline) as f:
            baseline = json.load(f)
        problems += check(baseline, fresh, [parse_gate(g) for g in args.gate])
    if args.ratio_gate:
        problems += check_ratios(
            fresh, [parse_ratio_gate(g) for g in args.ratio_gate])
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
